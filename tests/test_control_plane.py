"""Typed command control plane: Command serialization, middleware stack,
CallOptions, FlightError hierarchy, cache/pushdown interplay, put dedup."""
import json
import time

import numpy as np
import pytest

from repro.core import RecordBatch
from repro.core.flight import (
    Action,
    AuthTokenMiddleware,
    CallOptions,
    FlightClient,
    FlightClusterClient,
    FlightClusterServer,
    FlightDescriptor,
    FlightError,
    FlightNotFound,
    FlightTimedOut,
    FlightUnauthenticated,
    FlightUnavailable,
    FlightUnavailableError,
    InMemoryFlightServer,
    LoggingMiddleware,
    QueryCommand,
    RangeReadCommand,
    ServerMiddleware,
    StagedPutCommand,
    Ticket,
    error_from_wire,
    parse_command,
)
from repro.query import QueryPlan, col, execute


def make_batches(n=4, rows=1000, seed=0):
    rng = np.random.default_rng(seed)
    return [RecordBatch.from_numpy({
        "a": rng.integers(0, 100, rows).astype(np.int64),
        "b": rng.standard_normal(rows),
    }) for _ in range(n)]


def server_stats(client):
    return json.loads(client.do_action("server-stats")[0].body)


# --------------------------------------------------------------------------
# Command serialization
# --------------------------------------------------------------------------


class TestCommands:
    def test_range_read_golden_bytes(self):
        """Pin the versioned binary layout: any change is a wire break."""
        cmd = RangeReadCommand("ds", 0, 4, shard=2)
        assert cmd.to_bytes().hex() == (
            "c2"          # COMMAND_MAGIC
            "01"          # version 1
            "01"          # type: RangeRead
            "0200" "6473"  # u16 len + "ds"
            "0000000000000000"  # start=0  (i64 LE)
            "0400000000000000"  # stop=4   (i64 LE)
            "02000000"          # shard=2  (i32 LE)
        )
        assert parse_command(cmd.to_bytes()) == cmd

    def test_query_command_golden_bytes(self):
        plan = QueryPlan("t", projection=["a"])
        cmd = QueryCommand.for_plan(plan, 1, 3, shard=0)
        raw = cmd.to_bytes()
        head = "c2" "01" "02" + "0100000000000000" + "0300000000000000" + "00000000"
        assert raw.hex().startswith(head)
        back = parse_command(raw)
        assert back == cmd
        assert back.plan.dataset == "t" and back.plan.projection == ["a"]

    def test_staged_put_roundtrip(self):
        cmd = StagedPutCommand("ds", "txn-42", "commit")
        assert parse_command(cmd.to_bytes()) == cmd
        assert cmd.to_bytes()[0] == 0xC2

    def test_exchange_command_golden_bytes(self):
        """Pin 0xC2 type 4: service string + u32-length JSON params."""
        from repro.core.flight import ExchangeCommand

        cmd = ExchangeCommand.for_service("filter", threshold=3)
        assert cmd.to_bytes().hex() == (
            "c2"            # COMMAND_MAGIC
            "01"            # version 1
            "04"            # type: Exchange
            "0600" "66696c746572"   # u16 len + "filter"
            "10000000"              # u32 params length = 16
            + b'{"threshold": 3}'.hex()
        )
        assert parse_command(cmd.to_bytes()) == cmd
        assert parse_command(cmd.to_bytes()).params == {"threshold": 3}

    def test_legacy_json_ticket_still_parses(self):
        raw = json.dumps({"dataset": "ds", "start": 1, "stop": 3, "shard": 0}).encode()
        cmd = parse_command(raw)
        assert isinstance(cmd, RangeReadCommand)
        assert (cmd.dataset, cmd.start, cmd.stop, cmd.shard) == ("ds", 1, 3, 0)

    def test_legacy_bare_queryplan_json_parses_as_query(self):
        plan = QueryPlan("taxi", predicate=col("b") > 0)
        cmd = parse_command(plan.serialize())
        assert isinstance(cmd, QueryCommand)
        assert cmd.plan.dataset == "taxi" and cmd.start == 0 and cmd.stop == -1

    def test_grouped_queryplan_golden_bytes(self):
        """Pin the extended plan JSON: ``group_by`` rides inside the opaque
        plan payload — the 0xC2 framing around it is unchanged."""
        plan = QueryPlan("t", aggregations=[("mean", "v")], group_by=["g"])
        assert plan.serialize() == (
            b'{"dataset": "t", "projection": null, "predicate": null,'
            b' "aggregations": [["mean", "v"]], "limit": null,'
            b' "group_by": ["g"]}'
        )
        cmd = QueryCommand.for_plan(plan, 1, 3, shard=0)
        raw = cmd.to_bytes()
        # identical framing bytes as the pre-group-by golden test above
        head = "c2" "01" "02" + "0100000000000000" + "0300000000000000" + "00000000"
        assert raw.hex().startswith(head)
        back = parse_command(raw)
        assert back == cmd
        assert back.plan.group_by == ["g"]
        assert back.plan.aggregations == [("mean", "v")]

    def test_legacy_plan_without_group_by_still_parses_and_executes(self):
        """A pre-PR-9 plan JSON (no ``group_by`` key) must deserialize to an
        ungrouped plan and execute unchanged."""
        legacy = json.dumps({
            "dataset": "t", "projection": ["a"],
            "predicate": (col("a") > 50).to_json(),
            "aggregations": [], "limit": None,
        }).encode()
        cmd = parse_command(legacy)
        assert isinstance(cmd, QueryCommand)
        assert cmd.plan.group_by == []
        batches = make_batches(n=2, rows=200)
        out = list(execute(cmd.plan, batches))
        expect = sum(int((b.column("a").to_numpy() > 50).sum()) for b in batches)
        assert sum(b.num_rows for b in out) == expect
        assert all(b.schema.names == ["a"] for b in out)

    def test_ticket_range_shim(self):
        t = Ticket.for_range("ds", 2, 5, shard=1)
        assert t.raw[0] == 0xC2  # binary by default
        with pytest.warns(DeprecationWarning, match="Ticket.command"):
            assert t.range() == {"dataset": "ds", "start": 2, "stop": 5, "shard": 1}
        # extras (legacy) fall back to JSON and survive the round trip
        t2 = Ticket.for_range("ds", 0, 1, priority="high")
        with pytest.warns(DeprecationWarning):
            assert t2.range()["priority"] == "high"

    def test_unparseable_command_is_typed_error(self):
        from repro.core.flight import FlightInvalidArgument
        with pytest.raises(FlightInvalidArgument):
            parse_command(b"\xff\x00garbage")
        with pytest.raises(FlightInvalidArgument):
            parse_command(b"")

    def test_truncated_binary_command_is_typed_error(self):
        from repro.core.flight import FlightInvalidArgument
        for cmd in (RangeReadCommand("dataset", 0, 4, shard=2),
                    QueryCommand.for_plan(QueryPlan("t", projection=["a"])),
                    StagedPutCommand("ds", "txn-1")):
            raw = cmd.to_bytes()
            for cut in (3, 4, len(raw) // 2, len(raw) - 1):
                with pytest.raises(FlightInvalidArgument):
                    parse_command(raw[:cut])

    def test_staged_put_ticket_rejected_by_cluster_head_too(self):
        from repro.core.flight import FlightInvalidArgument
        cl = FlightClusterServer(num_shards=2)
        cl.add_dataset("ds", make_batches(2))
        t = Ticket.for_command(StagedPutCommand("ds", "txn-1"))
        for target in (cl, cl.shards[0]):
            with pytest.raises(FlightInvalidArgument):
                FlightClient(target).do_get(t)


# --------------------------------------------------------------------------
# middleware
# --------------------------------------------------------------------------


class Recorder(ServerMiddleware):
    def __init__(self, name, log):
        self.name, self.log = name, log

    def on_call(self, ctx):
        self.log.append(("call", self.name, ctx.method))

    def on_complete(self, ctx, error):
        self.log.append(("done", self.name, type(error).__name__ if error else None))


class TestMiddleware:
    def test_ordering_and_completion(self):
        log = []
        srv = InMemoryFlightServer(middleware=[Recorder("A", log), Recorder("B", log)])
        srv.add_dataset("ds", make_batches(1))
        srv.serve_tcp()
        try:
            FlightClient(f"tcp://127.0.0.1:{srv.port}").list_flights()
            calls = [e for e in log if e[2] == "ListFlights" or e[0] == "done"]
            assert calls == [
                ("call", "A", "ListFlights"), ("call", "B", "ListFlights"),
                ("done", "B", None), ("done", "A", None),  # completion reversed
            ]
        finally:
            srv.shutdown()

    def test_auth_short_circuits_later_middleware(self):
        log = []
        srv = InMemoryFlightServer(middleware=[
            Recorder("pre", log), AuthTokenMiddleware("s3cret"), Recorder("post", log)])
        srv.add_dataset("ds", make_batches(1))
        srv.serve_tcp()
        try:
            with pytest.raises(FlightUnauthenticated):
                FlightClient(f"tcp://127.0.0.1:{srv.port}").list_flights()
            assert ("call", "pre", "ListFlights") in log
            assert not any(e[1] == "post" and e[0] == "call" for e in log)
            # pre's completion hook saw the typed error
            assert ("done", "pre", "FlightUnauthenticated") in log
            # good token flows through to post
            FlightClient(f"tcp://127.0.0.1:{srv.port}", token="s3cret").list_flights()
            assert ("call", "post", "ListFlights") in log
        finally:
            srv.shutdown()

    def test_auth_token_kwarg_installs_middleware(self):
        srv = InMemoryFlightServer(auth_token="tok")
        assert any(isinstance(m, AuthTokenMiddleware) for m in srv.middleware.items)

    def test_metrics_middleware_counts_verbs(self):
        srv = InMemoryFlightServer().serve_tcp()
        srv.add_dataset("ds", make_batches(1))
        try:
            c = FlightClient(f"tcp://127.0.0.1:{srv.port}")
            c.list_flights()
            info = c.get_flight_info(FlightDescriptor.for_path("ds"))
            c.do_get(info.endpoints[0].ticket).read_all()
            with pytest.raises(FlightNotFound):
                c.get_flight_info(FlightDescriptor.for_path("nope"))
            verbs = server_stats(c)["verbs"]
            assert verbs["calls"]["ListFlights"] == 1
            assert verbs["calls"]["GetFlightInfo"] == 2
            assert verbs["calls"]["DoGet"] == 1
            assert verbs["errors"]["GetFlightInfo"] == 1
        finally:
            srv.shutdown()

    def test_logging_middleware_records_lines(self):
        mw = LoggingMiddleware()
        srv = InMemoryFlightServer(middleware=[mw]).serve_tcp()
        srv.add_dataset("ds", make_batches(1))
        try:
            FlightClient(f"tcp://127.0.0.1:{srv.port}").list_flights()
            assert "ListFlights ok" in mw.lines
        finally:
            srv.shutdown()


# --------------------------------------------------------------------------
# typed errors over the wire
# --------------------------------------------------------------------------


class TestTypedErrors:
    def test_not_found_roundtrips_with_detail(self):
        srv = InMemoryFlightServer().serve_tcp()
        try:
            c = FlightClient(f"tcp://127.0.0.1:{srv.port}")
            with pytest.raises(FlightNotFound) as ei:
                c.get_flight_info(FlightDescriptor.for_path("ghost"))
            assert ei.value.detail["dataset"] == "ghost"
        finally:
            srv.shutdown()

    def test_pooled_connection_survives_typed_errors(self):
        """A typed refusal leaves the channel clean and pooled (no leak)."""
        srv = InMemoryFlightServer().serve_tcp()
        srv.add_dataset("ds", make_batches(1))
        try:
            c = FlightClient(f"tcp://127.0.0.1:{srv.port}")
            for _ in range(3):
                with pytest.raises(FlightNotFound):
                    list(c.do_get(Ticket.for_range("nope", 0, 1)))
            assert c._conn_pool.qsize() == 1
            assert len(c.list_flights()) == 1  # channel still healthy
        finally:
            srv.shutdown()

    def test_unauthenticated_is_typed_over_tcp(self):
        srv = InMemoryFlightServer(auth_token="tok").serve_tcp()
        try:
            with pytest.raises(FlightUnauthenticated):
                FlightClient(f"tcp://127.0.0.1:{srv.port}").list_flights()
        finally:
            srv.shutdown()

    def test_unknown_code_degrades_to_base_error(self):
        e = error_from_wire({"error": "boom", "code": "from_the_future"})
        assert type(e) is FlightError and str(e) == "boom"

    def test_unavailable_alias_is_same_class(self):
        assert FlightUnavailableError is FlightUnavailable  # deprecation shim


# --------------------------------------------------------------------------
# CallOptions
# --------------------------------------------------------------------------


class SlowServer(InMemoryFlightServer):
    def do_action_impl(self, action):
        if action.type == "sleep":
            time.sleep(float(action.body.decode() or "1"))
            return []
        return super().do_action_impl(action)


class TestCallOptions:
    def test_timeout_fires_as_flight_timed_out(self):
        srv = SlowServer().serve_tcp()
        try:
            c = FlightClient(f"tcp://127.0.0.1:{srv.port}")
            t0 = time.perf_counter()
            with pytest.raises(FlightTimedOut) as ei:
                c.do_action(Action("sleep", b"2.0"), options=CallOptions(timeout=0.2))
            assert time.perf_counter() - t0 < 1.5
            assert ei.value.detail["timeout"] == pytest.approx(0.2)
            # the timed-out connection was discarded, not pooled; a fresh
            # call works and never sees the stale late reply
            assert c._conn_pool.qsize() == 0
            assert c.do_action("health")[0].body == b"ok"
        finally:
            srv.shutdown()

    def test_per_call_wire_codec_override(self):
        srv = InMemoryFlightServer().serve_tcp()  # binary default
        srv.add_dataset("ds", make_batches(2))
        try:
            c = FlightClient(f"tcp://127.0.0.1:{srv.port}")
            info = c.get_flight_info(FlightDescriptor.for_path("ds"))
            base = c.do_get(info.endpoints[0].ticket).read_all()
            asked = c.do_get(info.endpoints[0].ticket,
                             options=CallOptions(wire_codec="json", coalesce=False)).read_all()
            assert asked.num_rows == base.num_rows
            assert all(a == b for a, b in zip(asked.batches, base.batches))
            # the override bypassed the cache (its entries hold binary frames)
            assert server_stats(c)["wire_codec"] == "binary"
        finally:
            srv.shutdown()

    def test_unknown_wire_codec_is_typed_refusal_not_crash(self):
        """A bogus per-call codec must be refused before the stream starts —
        not a ValueError killing the server's handler thread."""
        from repro.core.flight import FlightInvalidArgument
        srv = InMemoryFlightServer().serve_tcp()
        srv.add_dataset("ds", make_batches(1))
        try:
            c = FlightClient(f"tcp://127.0.0.1:{srv.port}")
            info = c.get_flight_info(FlightDescriptor.for_path("ds"))
            with pytest.raises(FlightInvalidArgument):
                c.do_get(info.endpoints[0].ticket,
                         options=CallOptions(wire_codec="bogus")).read_all()
            # connection survived the refusal and still serves
            assert c.do_get(info.endpoints[0].ticket).read_all().num_rows == 1000
        finally:
            srv.shutdown()

    def test_default_options_on_client(self):
        srv = SlowServer().serve_tcp()
        try:
            c = FlightClient(f"tcp://127.0.0.1:{srv.port}",
                             options=CallOptions(timeout=0.2))
            with pytest.raises(FlightTimedOut):
                c.do_action(Action("sleep", b"2.0"))
        finally:
            srv.shutdown()


# --------------------------------------------------------------------------
# encode-cache / pushdown interplay (the PR-2 conflict, fixed)
# --------------------------------------------------------------------------


class TestQueryCacheInterplay:
    def test_passthrough_query_hits_cache_zero_encodes(self):
        srv = InMemoryFlightServer().serve_tcp()
        srv.add_dataset("ds", make_batches(4))
        try:
            c = FlightClient(f"tcp://127.0.0.1:{srv.port}")
            info = c.get_flight_info(FlightDescriptor.for_query(QueryPlan("ds")))
            c.read_all_parallel(info)  # warm: builds the cache once
            warm = server_stats(c)
            assert warm["encode_calls"] == 4  # one per stored batch, once
            for _ in range(3):
                t, _ = c.read_all_parallel(info)
                assert t.num_rows == 4000
            stats = server_stats(c)
            assert stats["encode_calls"] == warm["encode_calls"]  # zero since warm
            assert stats["encode_cache_hits"] > warm["encode_cache_hits"]
            assert stats["queries_executed"] == 0  # never hit the engine
        finally:
            srv.shutdown()

    def test_predicated_query_does_not_poison_cache(self):
        srv = InMemoryFlightServer().serve_tcp()
        srv.add_dataset("ds", make_batches(4))
        try:
            c = FlightClient(f"tcp://127.0.0.1:{srv.port}")
            pass_info = c.get_flight_info(FlightDescriptor.for_query(QueryPlan("ds")))
            c.read_all_parallel(pass_info)  # warm the cache
            warm = server_stats(c)
            plan = QueryPlan("ds", projection=["a"], predicate=col("b") > 0.5)
            pred_info = c.get_flight_info(FlightDescriptor.for_query(plan))
            table, _ = c.read_all_parallel(pred_info)
            mid = server_stats(c)
            # predicated read executed server-side, encoding per request ...
            assert mid["queries_executed"] == len(pred_info.endpoints)
            assert mid["query_rows_out"] < mid["query_rows_in"]
            assert mid["encode_cache_misses"] == warm["encode_cache_misses"]
            # ... and the warm pass-through entry is still served encode-free
            t, _ = c.read_all_parallel(pass_info)
            after = server_stats(c)
            assert t.num_rows == 4000
            assert after["encode_calls"] == mid["encode_calls"]
            assert after["encode_cache_hits"] > mid["encode_cache_hits"]
        finally:
            srv.shutdown()

    def test_predicated_results_match_client_side_filter(self):
        srv = InMemoryFlightServer()
        batches = make_batches(4)
        srv.add_dataset("ds", batches)
        plan = QueryPlan("ds", projection=["a"], predicate=col("b") > 0.5)
        c = FlightClient(srv)
        info = c.get_flight_info(FlightDescriptor.for_query(plan))
        table, _ = c.read_all_parallel(info)
        want = sum(b.num_rows for b in execute(plan, batches))
        assert table.num_rows == want and table.schema.names == ["a"]

    def test_ranged_query_descriptor_bounds_planning(self):
        """GetFlightInfo(QueryCommand with [start, stop)) must only touch
        that slice of the stored batches."""
        srv = InMemoryFlightServer()
        batches = make_batches(4)
        srv.add_dataset("ds", batches)
        plan = QueryPlan("ds", predicate=col("b") > 0.0)
        c = FlightClient(srv)
        info = c.get_flight_info(FlightDescriptor.for_query(plan, 1, 3))
        table, _ = c.read_all_parallel(info)
        want = sum(b.num_rows for b in execute(plan, batches[1:3]))
        assert table.num_rows == want


# --------------------------------------------------------------------------
# sharded query pushdown through the cluster head
# --------------------------------------------------------------------------


class TestClusterQueryPushdown:
    @pytest.mark.parametrize("transport", ["inproc", "tcp"])
    def test_shard_side_execution_matches_client_filter(self, transport):
        cl = FlightClusterServer(num_shards=4)
        batches = make_batches(8, rows=500)
        cl.add_dataset("ds", batches)
        try:
            if transport == "tcp":
                cl.serve_tcp()
                cc = FlightClusterClient(f"tcp://127.0.0.1:{cl.port}", max_streams=4)
            else:
                cc = FlightClusterClient(cl, max_streams=4)
            plan = QueryPlan("ds", projection=["a"], predicate=col("b") > 0.25)
            info = cc.query_info(plan)
            assert len(info.endpoints) == 4  # one query endpoint per shard
            assert {ep.shard for ep in info.endpoints} == {0, 1, 2, 3}
            table, stats = cc.query(plan)
            want = sum(b.num_rows for b in execute(plan, batches))
            assert table.num_rows == want
            assert table.schema.names == ["a"]
            assert stats.streams == 4
            # per-shard counters prove filtering ran where the data lives
            for shard in cl.shards:
                st = json.loads(shard.do_action_impl(Action("server-stats"))[0].body)
                assert st["queries_executed"] >= 1
                assert 0 < st["query_rows_out"] < st["query_rows_in"]
        finally:
            cl.shutdown()

    def test_headless_query_ticket_gathers_at_head(self):
        cl = FlightClusterServer(num_shards=2)
        batches = make_batches(4)
        cl.add_dataset("ds", batches)
        plan = QueryPlan("ds", predicate=col("a") < 50)
        got = FlightClient(cl).do_get_query(plan).read_all()
        want = sum(b.num_rows for b in execute(plan, cl.dataset("ds")))
        assert got.num_rows == want

    def test_ranged_query_ticket_at_head_honors_slice(self):
        cl = FlightClusterServer(num_shards=2)
        cl.add_dataset("ds", make_batches(4))
        plan = QueryPlan("ds", predicate=col("a") < 50)
        t = Ticket.for_command(QueryCommand.for_plan(plan, 0, 2))
        got = FlightClient(cl).do_get(t).read_all()
        want = sum(b.num_rows for b in execute(plan, cl.dataset("ds")[0:2]))
        assert got.num_rows == want

    def test_cluster_rejects_ranged_query_descriptor(self):
        from repro.core.flight import FlightInvalidArgument
        cl = FlightClusterServer(num_shards=2)
        cl.add_dataset("ds", make_batches(2))
        plan = QueryPlan("ds")
        with pytest.raises(FlightInvalidArgument):
            FlightClient(cl).get_flight_info(FlightDescriptor.for_query(plan, 0, 1))


# --------------------------------------------------------------------------
# DoPut dedup guard (first step of the two-phase-put roadmap item)
# --------------------------------------------------------------------------


class TestPutDedup:
    def test_identical_retried_put_is_dropped(self):
        srv = InMemoryFlightServer().serve_tcp()
        try:
            c = FlightClient(f"tcp://127.0.0.1:{srv.port}")
            payload = make_batches(2, rows=100, seed=3)
            for i in range(2):  # second put == a retry of the first
                w = c.do_put(FlightDescriptor.for_path("up"), payload[0].schema)
                w.write_batches(payload)
                stats = w.close()
            assert stats.get("deduped") is True
            assert sum(b.num_rows for b in srv.dataset("up")) == 200  # not 400
            assert server_stats(c)["put_dedup_hits"] == 1
        finally:
            srv.shutdown()

    def test_distinct_payloads_still_append(self):
        srv = InMemoryFlightServer()
        c = FlightClient(srv)
        for seed in (1, 2):
            batches = make_batches(1, rows=50, seed=seed)
            w = c.do_put(FlightDescriptor.for_path("up"), batches[0].schema)
            w.write_batch(batches[0])
            w.close()
        assert sum(b.num_rows for b in srv.dataset("up")) == 100

    def test_dedup_disabled_appends_twice(self):
        srv = InMemoryFlightServer(dedup_puts=False)
        c = FlightClient(srv)
        payload = make_batches(1, rows=50, seed=3)
        for _ in range(2):
            w = c.do_put(FlightDescriptor.for_path("up"), payload[0].schema)
            w.write_batch(payload[0])
            w.close()
        assert sum(b.num_rows for b in srv.dataset("up")) == 100

    def test_scheduler_put_retries_transient_failure_without_duplicates(self):
        """A put stream that dies after the server committed is retried by the
        scheduler; the shard-side dedup guard makes the retry idempotent."""
        from repro.core.flight import ParallelStreamScheduler

        srv = InMemoryFlightServer().serve_tcp()
        try:
            inner = FlightClient(f"tcp://127.0.0.1:{srv.port}")
            fails = {"n": 1}

            class FlakyWriter:
                def __init__(self, w):
                    self._w = w

                def write_batch(self, b):
                    self._w.write_batch(b)

                def close(self):
                    out = self._w.close()  # server committed the payload ...
                    if fails["n"]:
                        fails["n"] -= 1
                        raise FlightUnavailable("ack lost")  # ... but the ack was lost
                    return out

            class FlakyClient:
                def do_get(self, ticket, **kw):
                    return inner.do_get(ticket, **kw)

                def do_put(self, descriptor, schema, **kw):
                    return FlakyWriter(inner.do_put(descriptor, schema, **kw))

            sched = ParallelStreamScheduler(lambda loc: FlakyClient(), put_retries=1)
            payload = make_batches(2, rows=100, seed=5)
            stats = sched.put(FlightDescriptor.for_path("up"), payload[0].schema,
                              [(None, payload)])
            assert sched.retries == 1
            assert sum(b.num_rows for b in srv.dataset("up")) == 200  # no dup
        finally:
            srv.shutdown()

    def test_cluster_write_retry_end_to_end(self):
        """Re-issuing the same cluster write within the dedup window does not
        double rows on any shard (the FlightClusterClient.write retry story)."""
        cl = FlightClusterServer(num_shards=3)
        cc = FlightClusterClient(cl)
        batches = make_batches(6, rows=100, seed=11)
        cc.write("ds", batches)
        cc.write("ds", batches)  # retry after a presumed partial failure
        table, _ = cc.read("ds")
        assert table.num_rows == 600

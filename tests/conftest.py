"""Make `pytest tests/` work from the repo root without PYTHONPATH set."""
import sys
from pathlib import Path

import pytest

HERE = Path(__file__).resolve().parent
SRC = HERE.parent / "src"
for p in (str(SRC), str(HERE)):
    if p not in sys.path:
        sys.path.insert(0, p)

# hypothesis is a dev-only dependency (requirements-dev.txt, installed in CI).
# Offline containers fall back to a deterministic in-tree stub so the suite
# still collects and the property tests run with random examples.
try:
    import hypothesis  # noqa: F401
except ImportError:
    import _hypothesis_stub

    _hypothesis_stub.install()


def pytest_addoption(parser):
    parser.addoption(
        "--runslow", action="store_true", default=False,
        help="also run tests marked @pytest.mark.slow",
    )


def pytest_collection_modifyitems(config, items):
    if config.getoption("--runslow"):
        return
    skip_slow = pytest.mark.skip(reason="slow test: use --runslow to enable")
    for item in items:
        if "slow" in item.keywords:
            item.add_marker(skip_slow)

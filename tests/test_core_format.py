"""Core columnar format: buffers, arrays, RecordBatch, IPC round-trips."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import Array, Buffer, RecordBatch, read_stream, write_stream
from repro.core import types
from repro.core.buffer import Bitmap
from repro.core.ipc import encode_batch


class TestBuffer:
    def test_alignment(self):
        for n in (1, 63, 64, 1000):
            assert Buffer.allocate(n).is_aligned

    def test_zero_copy_view(self):
        arr = np.arange(100, dtype=np.int64)
        buf = Buffer.from_array(arr)
        assert buf.address == arr.ctypes.data  # no copy
        assert np.array_equal(buf.view(np.int64), arr)

    def test_slice_shares_memory(self):
        buf = Buffer.from_array(np.arange(10, dtype=np.int32))
        s = buf.slice(4, 8)
        assert s.address == buf.address + 4

    def test_bitmap_roundtrip(self):
        mask = np.array([True, False, True, True, False, True, False, False, True])
        bm = Bitmap.from_bools(mask)
        assert np.array_equal(bm.to_bools(), mask)
        assert bm.null_count() == 4
        assert bm.is_valid(0) and not bm.is_valid(1)


class TestArray:
    def test_primitive_zero_copy(self):
        vals = np.arange(1000, dtype=np.float32)
        arr = Array.from_numpy(vals)
        assert arr.to_numpy().ctypes.data == vals.ctypes.data

    def test_nulls(self):
        arr = Array.from_pylist([1, None, 3])
        assert arr.null_count == 1
        assert arr.to_pylist() == [1, None, 3]

    def test_strings(self):
        arr = Array.from_pylist(["Arrow", "Data", "!"])
        assert arr.to_pylist() == ["Arrow", "Data", "!"]

    def test_lists(self):
        arr = Array.from_pylist([[1, 2], [], None, [3]])
        assert arr.to_pylist() == [[1, 2], [], None, [3]]

    def test_slice_is_zero_copy_and_correct(self):
        arr = Array.from_numpy(np.arange(100, dtype=np.int32))
        s = arr.slice(10, 20)
        assert len(s) == 20 and s.to_pylist()[0] == 10
        assert s.buffers[0].address == arr.buffers[0].address  # shares buffer

    def test_take(self):
        arr = Array.from_numpy(np.arange(10, dtype=np.int64))
        assert arr.take(np.array([3, 1, 7])).to_pylist() == [3, 1, 7]


class TestRecordBatch:
    def test_paper_table1(self):
        """The exact example from the paper's Table 1."""
        b = RecordBatch.from_pydict({
            "X": [555, 56565, None],
            "Y": ["Arrow", "Data", "!"],
            "Z": [5.7866, 0.0, 3.14],
        })
        assert b.num_rows == 3 and b.num_columns == 3
        assert b.column("X").null_count == 1
        assert b.to_pydict()["Y"] == ["Arrow", "Data", "!"]

    def test_select_zero_copy(self):
        b = RecordBatch.from_numpy({"a": np.arange(5), "b": np.ones(5)})
        s = b.select(["b"])
        assert s.schema.names == ["b"]
        assert s.column("b").buffers[0].address == b.column("b").buffers[0].address

    def test_schema_mismatch_raises(self):
        with pytest.raises(ValueError):
            RecordBatch.from_pydict({"a": [1, 2], "b": [1]})

    def test_filter(self):
        b = RecordBatch.from_numpy({"a": np.arange(10, dtype=np.int64)})
        out = b.filter(np.arange(10) % 2 == 0)
        assert out.column("a").to_pylist() == [0, 2, 4, 6, 8]


class TestIPC:
    def test_roundtrip_mixed(self):
        b = RecordBatch.from_pydict({
            "i": [1, None, 3], "s": ["a", "bb", "ccc"], "f": [0.5, 1.5, -2.0],
            "l": [[1, 2], None, [3]],
        })
        out = read_stream(write_stream([b]))
        assert out[0] == b

    def test_decode_is_zero_copy_views(self):
        b = RecordBatch.from_numpy({"x": np.arange(1 << 12, dtype=np.int64)})
        data = write_stream([b])
        out = read_stream(data)[0]
        # decoded column must be a view into one body allocation, not a copy
        assert out.column("x").buffers[0].nbytes == (1 << 12) * 8

    def test_sliced_batch_roundtrip(self):
        b = RecordBatch.from_pydict({"s": ["aa", "bb", "cc", "dd"], "v": [1, 2, 3, 4]})
        out = read_stream(write_stream([b.slice(1, 2)]))[0]
        assert out.to_pydict() == {"s": ["bb", "cc"], "v": [2, 3]}

    def test_scatter_gather_parts_match_bytes(self):
        b = RecordBatch.from_numpy({"x": np.arange(100, dtype=np.float64)})
        msg = encode_batch(b)
        assert len(msg.to_bytes()) == msg.nbytes()


# ---------------------------------------------------------------------------
# property tests
# ---------------------------------------------------------------------------

pyval = st.one_of(st.none(), st.integers(-2**40, 2**40))
pystr = st.one_of(st.none(), st.text(max_size=12))


@settings(max_examples=40, deadline=None)
@given(st.lists(pyval, min_size=1, max_size=50))
def test_prop_int_column_roundtrip(values):
    b = RecordBatch.from_pydict({"c": values})
    assert read_stream(write_stream([b]))[0].to_pydict()["c"] == values


@settings(max_examples=40, deadline=None)
@given(st.lists(pystr, min_size=1, max_size=50))
def test_prop_str_column_roundtrip(values):
    b = RecordBatch.from_pydict({"c": values})
    assert read_stream(write_stream([b]))[0].to_pydict()["c"] == values


@settings(max_examples=25, deadline=None)
@given(st.lists(st.integers(0, 1000), min_size=2, max_size=60),
       st.data())
def test_prop_slice_equals_pylist_slice(values, data):
    b = RecordBatch.from_pydict({"c": values})
    i = data.draw(st.integers(0, len(values) - 1))
    j = data.draw(st.integers(i, len(values)))
    assert b.slice(i, j - i).to_pydict()["c"] == values[i:j]

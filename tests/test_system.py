"""End-to-end system behaviour: the paper's full story in one test each."""
import jax
import numpy as np
import pytest

from repro.configs import ARCH_IDS, SHAPES, cell_supported, get_config, input_specs


class TestAssignmentContract:
    """The deliverable-(f) contract: every arch × shape cell is well-defined."""

    def test_all_archs_have_configs(self):
        assert len(ARCH_IDS) == 10
        for arch in ARCH_IDS:
            cfg = get_config(arch)
            assert cfg.n_layers > 0 and cfg.vocab > 0

    def test_exact_assignment_numbers(self):
        cfg = get_config("deepseek_coder_33b")
        assert (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                cfg.d_ff, cfg.vocab) == (62, 7168, 56, 8, 19200, 32256)
        cfg = get_config("qwen3_moe_235b_a22b")
        assert (cfg.n_layers, cfg.d_model, cfg.moe.n_experts, cfg.moe.top_k,
                cfg.vocab) == (94, 4096, 128, 8, 151936)
        cfg = get_config("jamba_1_5_large_398b")
        assert (cfg.n_layers, cfg.d_model, cfg.moe.n_experts) == (72, 8192, 16)
        cfg = get_config("hubert_xlarge")
        assert (cfg.n_layers, cfg.d_model, cfg.vocab) == (48, 1280, 504)

    def test_cell_support_matrix(self):
        total = runnable = 0
        for arch in ARCH_IDS:
            cfg = get_config(arch)
            for name, spec in SHAPES.items():
                total += 1
                ok, why = cell_supported(cfg, spec)
                runnable += ok
                if not ok:
                    assert why  # every skip has a reason
        assert total == 40 and runnable == 31

    def test_input_specs_are_abstract(self):
        for arch in ARCH_IDS:
            cfg = get_config(arch)
            for name, spec in SHAPES.items():
                if not cell_supported(cfg, spec)[0]:
                    continue
                specs = input_specs(cfg, spec)
                for leaf in jax.tree.leaves(specs):
                    assert isinstance(leaf, jax.ShapeDtypeStruct)

    def test_param_counts_match_billing_names(self):
        """Config names claim a size; the analytic count should be in range."""
        expect = {
            "deepseek_coder_33b": (30e9, 36e9),
            "qwen3_moe_235b_a22b": (200e9, 260e9),
            "jamba_1_5_large_398b": (330e9, 440e9),
            "phi4_mini_3_8b": (3.2e9, 4.4e9),
            "yi_6b": (5.5e9, 6.6e9),
            "internlm2_1_8b": (1.5e9, 2.1e9),
            "xlstm_350m": (0.25e9, 0.5e9),
            # assignment fixes 48L x 64e x d_ff 1408 => ~28 B total (the HF
            # Moonlight-16B uses 27 layers; assignment numbers win)
            "moonshot_v1_16b_a3b": (26e9, 31e9),
            "phi_3_vision_4_2b": (3.5e9, 4.5e9),
            "hubert_xlarge": (0.8e9, 1.1e9),
        }
        for arch, (lo, hi) in expect.items():
            n = get_config(arch).param_count()
            assert lo <= n <= hi, (arch, f"{n:.3e}")


class TestDryRunArtifacts:
    """The committed sweep results must exist and be complete."""

    def test_all_cells_recorded_both_meshes(self):
        import json
        from pathlib import Path
        art = Path(__file__).resolve().parents[1] / "experiments" / "artifacts"
        if not art.exists():
            pytest.skip("dry-run artifacts not generated yet")
        recs = [json.loads(f.read_text()) for f in art.glob("*.json")]
        assert len(recs) == 80  # 40 cells × 2 meshes
        ok = [r for r in recs if r.get("status") == "ok"]
        skipped = [r for r in recs if r.get("status") == "skipped"]
        failed = [r for r in recs if r.get("status") not in ("ok", "skipped")]
        assert not failed, [(r["arch"], r["shape"], r["mesh"]) for r in failed]
        assert len(ok) == 62 and len(skipped) == 18

    def test_roofline_terms_present(self):
        import json
        from pathlib import Path
        art = Path(__file__).resolve().parents[1] / "experiments" / "artifacts"
        if not art.exists():
            pytest.skip("no artifacts")
        for f in art.glob("*.json"):
            r = json.loads(f.read_text())
            if r.get("status") != "ok":
                continue
            t = r["roofline"]
            assert set(t) >= {"compute_s", "memory_s", "collective_s", "dominant"}
            assert t[t["dominant"]] == max(t["compute_s"], t["memory_s"],
                                           t["collective_s"])

"""Storage provider plane: backend conformance, durable staging, recovery.

One parametrized conformance suite runs the same contract over all three
backends (memory, disk, remote-Flight proxy); the disk-specific classes
cover what only a durable backend can promise — byte-identical re-serve
after a restart and recovery of a prepared-but-uncommitted 2PC stage
(the durability gap the RAM-only staging of the transactions PR left open).
"""
import json
import warnings

import numpy as np
import pytest

from repro.core import RecordBatch
from repro.core.flight import (
    Action,
    DiskStorageProvider,
    FlightClient,
    FlightClusterClient,
    FlightClusterServer,
    FlightDescriptor,
    FlightInvalidArgument,
    FlightNotFound,
    InMemoryFlightServer,
    MemoryStorageProvider,
    RemoteFlightProvider,
    ServerConfig,
    StagedPutCommand,
    StorageProvider,
    Ticket,
    make_provider,
)
from repro.core.ipc import write_stream


def make_batches(n=4, rows=200, seed=0):
    rng = np.random.default_rng(seed)
    return [RecordBatch.from_numpy({
        "k": rng.integers(0, 40, rows).astype(np.int64),
        "v": rng.standard_normal(rows),
    }) for _ in range(n)]


def stage_via_client(target, dataset, txn_id, batches):
    client = target if isinstance(target, FlightClient) else FlightClient(target)
    desc = FlightDescriptor.for_command(StagedPutCommand(dataset, txn_id, "stage"))
    w = client.do_put(desc, batches[0].schema)
    w.write_batches(batches)
    return w.close()


def txn_action(client, verb, txn_id, dataset="ds"):
    body = json.dumps({"txn_id": txn_id, "dataset": dataset}).encode()
    return json.loads(client.do_action(Action(verb, body))[0].body)


# --------------------------------------------------------------------------
# backend conformance: one contract, three implementations
# --------------------------------------------------------------------------


@pytest.fixture(params=["memory", "disk", "remote"])
def provider(request, tmp_path):
    if request.param == "memory":
        yield MemoryStorageProvider()
    elif request.param == "disk":
        p = DiskStorageProvider(tmp_path / "store")
        yield p
        p.close()
    else:
        backing = InMemoryFlightServer()
        p = RemoteFlightProvider(FlightClient(backing))
        yield p
        backing.shutdown()


class TestProviderConformance:
    def test_append_read_round_trip(self, provider):
        bs = make_batches(3)
        provider.append("ds", bs[0].schema, bs)
        assert provider.exists("ds")
        assert provider.list() == ["ds"]
        assert provider.read_batches("ds") == bs
        assert provider.schema("ds") == bs[0].schema

    def test_info_counts(self, provider):
        bs = make_batches(3, rows=100)
        provider.append("ds", bs[0].schema, bs)
        info = provider.info("ds")
        assert info["batches"] == 3 and info["rows"] == 300
        assert info["bytes"] == sum(b.nbytes() for b in bs)

    def test_read_slicing(self, provider):
        bs = make_batches(5)
        provider.append("ds", bs[0].schema, bs)
        assert provider.read_batches("ds", 1, 3) == bs[1:3]
        assert provider.read_batches("ds", 3) == bs[3:]

    def test_append_extends_replace_resets(self, provider):
        a, b = make_batches(2, seed=1), make_batches(3, seed=2)
        provider.append("ds", a[0].schema, a)
        provider.append("ds", a[0].schema, b)
        assert provider.info("ds")["batches"] == 5
        provider.replace("ds", b[0].schema, b)
        assert provider.read_batches("ds") == b

    def test_unknown_dataset_raises_typed(self, provider):
        for op in (provider.schema, provider.info, provider.read_batches):
            with pytest.raises(FlightNotFound):
                op("ghost")

    def test_drop_is_idempotent(self, provider):
        bs = make_batches(1)
        provider.append("ds", bs[0].schema, bs)
        provider.drop("ds")
        provider.drop("ds")  # second drop: no error
        assert not provider.exists("ds")
        assert provider.list() == []

    def test_stage_commit_appends_atomically(self, provider):
        base, staged = make_batches(2, seed=3), make_batches(2, seed=4)
        provider.append("ds", base[0].schema, base)
        provider.stage("t1", "ds", staged[0].schema, staged)
        assert provider.read_batches("ds") == base  # invisible until commit
        provider.commit_stage("t1")
        assert provider.read_batches("ds") == base + staged

    def test_stage_discard_leaves_no_trace(self, provider):
        staged = make_batches(2, seed=5)
        provider.stage("t1", "new-ds", staged[0].schema, staged)
        provider.discard_stage("t1")
        assert not provider.exists("new-ds")
        # committing after discard is a typed error on every backend (the
        # remote proxy surfaces the backing server's commit-after-abort)
        with pytest.raises((FlightNotFound, FlightInvalidArgument)):
            provider.commit_stage("t1")

    def test_commit_unknown_txn_raises(self, provider):
        with pytest.raises(FlightNotFound):
            provider.commit_stage("never-staged")

    def test_stats_carry_kind(self, provider):
        assert provider.stats()["kind"] == provider.kind


class TestMakeProvider:
    def test_specs(self, tmp_path):
        assert isinstance(make_provider(None), MemoryStorageProvider)
        assert isinstance(make_provider("memory"), MemoryStorageProvider)
        disk = make_provider(f"disk:{tmp_path / 'd'}")
        assert isinstance(disk, DiskStorageProvider)
        ready = MemoryStorageProvider()
        assert make_provider(ready) is ready

    def test_bad_specs_rejected(self):
        with pytest.raises(FlightInvalidArgument):
            make_provider("s3://nope")
        with pytest.raises(FlightInvalidArgument):
            make_provider(42)


# --------------------------------------------------------------------------
# server over a disk backend: durability end to end
# --------------------------------------------------------------------------


class TestDiskBackedServer:
    def test_restart_reserves_byte_identical(self, tmp_path):
        """Golden check: the stream a restarted server serves is the same
        *bytes* the original server served, not merely equal batches."""
        spec = f"disk:{tmp_path / 'store'}"
        bs = make_batches(4)
        srv = InMemoryFlightServer(storage=spec)
        srv.add_dataset("ds", bs)
        before = [write_stream([b]) for b in FlightClient(srv).do_get(
            Ticket.for_range("ds", 0, -1))]
        srv.shutdown()

        srv2 = InMemoryFlightServer(storage=spec)
        got = list(FlightClient(srv2).do_get(Ticket.for_range("ds", 0, -1)))
        after = [write_stream([b]) for b in got]
        srv2.shutdown()
        assert before == after
        assert got == bs

    def test_restart_recovers_catalog_and_stats(self, tmp_path):
        spec = f"disk:{tmp_path / 'store'}"
        srv = InMemoryFlightServer(storage=spec)
        srv.add_dataset("a", make_batches(1, seed=1))
        srv.add_dataset("b", make_batches(2, seed=2))
        srv.shutdown()

        srv2 = InMemoryFlightServer(storage=spec)
        infos = {i.descriptor.key: i for i in srv2.list_flights_impl()}
        assert sorted(infos) == ["path:a", "path:b"]
        stats = json.loads(srv2.do_action_impl(Action("server-stats"))[0].body)
        assert stats["storage"]["kind"] == "disk"
        assert stats["storage"]["recovered_datasets"] == 2
        assert stats["storage"]["disk_bytes"] > 0
        srv2.shutdown()

    def test_warm_reads_hit_encode_cache_not_disk(self, tmp_path):
        srv = InMemoryFlightServer(storage=f"disk:{tmp_path / 'store'}")
        srv.add_dataset("ds", make_batches(4))
        c = FlightClient(f"tcp://127.0.0.1:{srv.serve_tcp().port}")
        t = Ticket.for_range("ds", 0, -1)
        list(c.do_get(t))
        maps_after_cold = srv.storage.stats()["mmap_reads"]
        for _ in range(3):
            list(c.do_get(t))
        assert srv.storage.stats()["mmap_reads"] == maps_after_cold
        assert srv.cache_hits >= 3  # warm path served from the encoded cache
        srv.shutdown()

    def test_prepared_stage_survives_restart(self, tmp_path):
        """The PR 4 durability gap: a server that voted yes in phase 1 and
        then died must still honor the coordinator's commit after restart."""
        spec = f"disk:{tmp_path / 'store'}"
        staged = make_batches(3, seed=7)
        srv = InMemoryFlightServer(storage=spec)
        stage_via_client(srv, "ds", "t-prep", staged)
        ack = txn_action(FlightClient(srv), "txn-prepare", "t-prep")
        assert ack["staged"]
        srv.shutdown()  # dies mid-2PC, after the yes vote

        srv2 = InMemoryFlightServer(storage=spec)
        stats = json.loads(srv2.do_action_impl(Action("server-stats"))[0].body)
        assert stats["staged_txns"] == 1
        assert not srv2.storage.exists("ds")  # still invisible
        ack = txn_action(FlightClient(srv2), "txn-commit", "t-prep")
        assert ack["committed"] and ack["rows"] == sum(b.num_rows for b in staged)
        assert srv2.dataset("ds") == staged
        srv2.shutdown()

    def test_unprepared_stage_recovered_then_abortable(self, tmp_path):
        spec = f"disk:{tmp_path / 'store'}"
        srv = InMemoryFlightServer(storage=spec)
        stage_via_client(srv, "ds", "t-orphan", make_batches(2, seed=8))
        srv.shutdown()

        srv2 = InMemoryFlightServer(storage=spec)
        ack = txn_action(FlightClient(srv2), "txn-abort", "t-orphan")
        assert ack["aborted"]
        assert srv2.storage.stats()["staged_txns_on_disk"] == 0
        srv2.shutdown()

    def test_cluster_restart_recovers_all_shards(self, tmp_path):
        spec = f"disk:{tmp_path / 'cluster'}"
        bs = make_batches(6, seed=9)
        cl = FlightClusterServer(num_shards=3, storage=spec)
        cl.add_dataset("ds", bs)
        t1, _ = FlightClusterClient(cl).read("ds")
        cl.shutdown()

        cl2 = FlightClusterServer(num_shards=3, storage=spec)
        t2, stats = FlightClusterClient(cl2).read("ds")
        assert stats.streams == 3  # every shard recovered its slice
        assert t1.combine() == t2.combine()
        cl2.shutdown()

    def test_shard_roots_are_disjoint(self, tmp_path):
        cl = FlightClusterServer(num_shards=2, storage=f"disk:{tmp_path / 'c'}")
        roots = {s.storage.root for s in cl.shards}
        assert len(roots) == 2
        cl.shutdown()


# --------------------------------------------------------------------------
# remote proxy in front of a backing server
# --------------------------------------------------------------------------


class TestRemoteProxyServer:
    def test_front_server_serves_remote_datasets(self):
        backing = InMemoryFlightServer()
        bs = make_batches(3, seed=11)
        backing.add_dataset("ds", bs)
        front = InMemoryFlightServer(
            storage=RemoteFlightProvider(FlightClient(backing)))
        c = FlightClient(front)
        assert [i.descriptor.key for i in c.list_flights()] == ["path:ds"]
        got = list(c.do_get(Ticket.for_range("ds", 0, -1)))
        assert got == bs
        assert front.storage.stats()["proxied_reads"] >= 1
        # a write through the front lands on the backing store
        w = c.do_put(FlightDescriptor.for_path("up"), bs[0].schema)
        w.write_batches(bs[:1])
        w.close()
        assert backing.dataset("up") == bs[:1]
        front.shutdown()
        backing.shutdown()


# --------------------------------------------------------------------------
# ServerConfig: the collected construction surface
# --------------------------------------------------------------------------


class TestServerConfig:
    def test_config_object_drives_the_server(self, tmp_path):
        cfg = ServerConfig(batches_per_endpoint=2, dedup_puts=False,
                           storage=f"disk:{tmp_path / 's'}")
        srv = InMemoryFlightServer(config=cfg)
        assert srv.config is cfg
        assert srv.batches_per_endpoint == 2
        assert srv.dedup_puts is False
        assert srv.storage.kind == "disk"
        srv.shutdown()

    def test_legacy_kwargs_still_route(self):
        srv = InMemoryFlightServer(auth_token="tok", batches_per_endpoint=3,
                                   dedup_puts=False)
        assert srv.config.auth_token == "tok"
        assert srv.config.batches_per_endpoint == 3
        assert srv.config.dedup_puts is False
        srv.shutdown()

    def test_explicit_kwarg_beats_config_field(self):
        cfg = ServerConfig(batches_per_endpoint=2)
        srv = InMemoryFlightServer(config=cfg, batches_per_endpoint=5)
        assert srv.batches_per_endpoint == 5
        assert cfg.batches_per_endpoint == 2  # the config object is not mutated
        srv.shutdown()

    def test_store_views_stay_dict_shaped(self):
        # the historical `_store`/`_schemas` peeks remain valid read views
        srv = InMemoryFlightServer()
        bs = make_batches(2)
        srv.add_dataset("ds", bs)
        assert "ds" in srv._store and "ghost" not in srv._store
        assert srv._store["ds"] == bs
        assert srv._schemas["ds"] == bs[0].schema
        assert list(srv._store) == ["ds"] and len(srv._store) == 1
        with pytest.raises(KeyError):
            srv._store["ghost"]
        srv.shutdown()


# --------------------------------------------------------------------------
# control-surface cleanup rode along: deprecations still warn exactly once
# --------------------------------------------------------------------------


class TestDeprecatedSurface:
    def test_ticket_range_warns(self):
        t = Ticket.for_range("ds", 0, 4)
        with pytest.warns(DeprecationWarning, match="Ticket.command"):
            t.range()

    def test_do_exchange_shim_warns(self):
        srv = InMemoryFlightServer()
        c = FlightClient(srv)
        b = make_batches(1)[0]
        with pytest.warns(DeprecationWarning, match="do_exchange_stream"):
            ex = c.do_exchange(FlightDescriptor.for_path("echo"), b.schema)
        assert ex.exchange(b) == b
        ex.close()
        srv.shutdown()

    def test_streaming_api_does_not_warn(self):
        srv = InMemoryFlightServer()
        c = FlightClient(srv)
        b = make_batches(1)[0]
        with warnings.catch_warnings():
            warnings.simplefilter("error", DeprecationWarning)
            ex = c.do_exchange_stream(FlightDescriptor.for_path("echo"), b.schema)
            ex.feed([b])
            assert list(ex) == [b]
            ex.close()
        srv.shutdown()

    def test_aggregate_action_is_native(self):
        # the query-service shim folded into the server: `aggregate` answers
        # on any InMemoryFlightServer, no subclass required
        from repro.query.engine import QueryPlan

        srv = InMemoryFlightServer()
        srv.add_dataset("t", make_batches(2, rows=50, seed=13))
        plan = QueryPlan("t", aggregations=[("sum", "v")])
        out = json.loads(FlightClient(srv).do_action(
            Action("aggregate", plan.serialize()))[0].body)
        expect = float(sum(b.column("v").to_numpy().sum()
                           for b in srv.dataset("t")))
        assert out["sum(v)"] == pytest.approx(expect)
        srv.shutdown()

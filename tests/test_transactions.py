"""Transactional staged DoPut: stage→commit→abort/GC across shards.

The invariants under test (ISSUE 4 acceptance criteria):

* a crashed writer's staged payloads are never readable and are GC'd after
  the TTL;
* a committed txn is visible on all shards or none;
* a reader racing a commit never sees a half-visible txn (per-shard, the
  visibility flip is atomic under the store lock);
* duplicate commits are idempotent, commit-after-abort and abort-after-
  commit are typed protocol errors.
"""
import json
import threading
import time

import numpy as np
import pytest

from repro.core import RecordBatch
from repro.core.flight import (
    Action,
    FlightClient,
    FlightClusterClient,
    FlightClusterServer,
    FlightDescriptor,
    FlightInvalidArgument,
    FlightNotFound,
    FlightUnavailable,
    InMemoryFlightServer,
    StagedPutCommand,
    parse_command,
    parse_txn_body,
)


def make_batches(n=8, rows=500, seed=0):
    rng = np.random.default_rng(seed)
    return [RecordBatch.from_numpy({
        "k": rng.integers(0, 40, rows).astype(np.int64),
        "v": rng.standard_normal(rows),
    }) for _ in range(n)]


def stage(server_or_client, dataset, txn_id, batches):
    """Stream ``batches`` as one staged DoPut stream."""
    client = (server_or_client if isinstance(server_or_client, FlightClient)
              else FlightClient(server_or_client))
    desc = FlightDescriptor.for_command(StagedPutCommand(dataset, txn_id, "stage"))
    w = client.do_put(desc, batches[0].schema)
    for b in batches:
        w.write_batch(b)
    return w.close()


def stats_of(server):
    return json.loads(server.do_action_impl(Action("server-stats"))[0].body)


def txn_action(client, verb, txn_id, dataset="ds", **extra):
    body = json.dumps({"txn_id": txn_id, "dataset": dataset, **extra}).encode()
    return json.loads(client.do_action(Action(verb, body))[0].body)


# --------------------------------------------------------------------------
# wire format
# --------------------------------------------------------------------------


class TestStagedPutWire:
    def test_all_three_phases_round_trip(self):
        for phase in ("stage", "commit", "abort"):
            cmd = StagedPutCommand("ds", "txn-7", phase)
            assert parse_command(cmd.to_bytes()) == cmd

    def test_phase_bytes_are_pinned(self):
        # the phase byte is the last byte: 0=stage, 1=commit, 2=abort —
        # a change here is a wire break (docs/wire-format.md)
        for i, phase in enumerate(("stage", "commit", "abort")):
            assert StagedPutCommand("d", "t", phase).to_bytes()[-1] == i

    def test_unknown_phase_rejected_both_directions(self):
        with pytest.raises(FlightInvalidArgument):
            StagedPutCommand("d", "t", "flush").to_bytes()
        raw = bytearray(StagedPutCommand("d", "t").to_bytes())
        raw[-1] = 9
        with pytest.raises(FlightInvalidArgument):
            parse_command(bytes(raw))

    def test_txn_body_accepts_binary_and_json(self):
        o = parse_txn_body(StagedPutCommand("ds", "t1", "commit").to_bytes())
        assert o == {"txn_id": "t1", "dataset": "ds"}
        o = parse_txn_body(b'{"txn_id": "t2", "expect_shards": [0, 1]}')
        assert o["txn_id"] == "t2" and o["expect_shards"] == [0, 1]
        with pytest.raises(FlightInvalidArgument):
            parse_txn_body(b"")
        with pytest.raises(FlightInvalidArgument):
            parse_txn_body(b'{"no": "txn"}')


# --------------------------------------------------------------------------
# single-server staging semantics
# --------------------------------------------------------------------------


class TestStagingStore:
    def test_staged_payload_invisible_until_commit(self):
        s = InMemoryFlightServer()
        c = FlightClient(s)
        batches = make_batches(4)
        stage(s, "ds", "t1", batches)
        # not listed, not gettable, not in the store
        with pytest.raises(FlightNotFound):
            c.get_flight_info(FlightDescriptor.for_path("ds"))
        assert "ds" not in s._store
        assert stats_of(s)["staged_txns"] == 1
        assert stats_of(s)["staged_bytes"] == sum(b.nbytes() for b in batches)
        ack = txn_action(c, "txn-commit", "t1")
        assert ack["committed"] and ack["rows"] == 4 * 500
        assert sum(b.num_rows for b in s.dataset("ds")) == 4 * 500
        assert stats_of(s)["staged_txns"] == 0
        assert stats_of(s)["txn_commits"] == 1

    def test_commit_appends_to_existing_dataset(self):
        s = InMemoryFlightServer()
        s.add_dataset("ds", make_batches(2))
        stage(s, "ds", "t1", make_batches(3, seed=1))
        assert len(s.dataset("ds")) == 2
        txn_action(FlightClient(s), "txn-commit", "t1")
        assert len(s.dataset("ds")) == 5

    def test_stage_does_not_invalidate_encode_cache_commit_does(self):
        s = InMemoryFlightServer()
        s.add_dataset("ds", make_batches(2))
        c = FlightClient(s)
        info = c.get_flight_info(FlightDescriptor.for_path("ds"))
        ticket = info.endpoints[0].ticket
        assert s.do_get_encoded(ticket) is not None  # build the cache
        assert stats_of(s)["encode_cache_misses"] == 1
        stage(s, "ds", "t1", make_batches(1, seed=2))
        s.do_get_encoded(ticket)
        assert stats_of(s)["encode_cache_hits"] == 1  # stage kept it warm
        txn_action(c, "txn-commit", "t1")
        assert stats_of(s)["encode_cache_datasets"] == 0  # commit dropped it

    def test_duplicate_commit_is_idempotent(self):
        s = InMemoryFlightServer()
        c = FlightClient(s)
        stage(s, "ds", "t1", make_batches(2))
        first = txn_action(c, "txn-commit", "t1")
        second = txn_action(c, "txn-commit", "t1")
        assert second["duplicate"] and second["committed"]
        assert second["rows"] == first["rows"]
        assert len(s.dataset("ds")) == 2  # not doubled
        assert stats_of(s)["txn_commits"] == 1

    def test_retried_stage_stream_dedups_within_txn(self):
        s = InMemoryFlightServer()
        batches = make_batches(2)
        stage(s, "ds", "t1", batches)
        ack = stage(s, "ds", "t1", batches)  # scheduler put retry, same bytes
        assert ack["deduped"]
        txn_action(FlightClient(s), "txn-commit", "t1")
        assert len(s.dataset("ds")) == 2

    def test_dedup_puts_off_keeps_identical_staged_streams(self):
        """Like the plain-put guard, stage dedup is opt-out: a server built
        with dedup_puts=False commits byte-identical parallel streams in
        full instead of collapsing them to one."""
        srv = InMemoryFlightServer(dedup_puts=False)
        c = FlightClient(srv)
        b = make_batches(1)[0]
        c.write_parallel(FlightDescriptor.for_path("ds"), [b] * 8,
                         max_streams=4, transactional=True)
        assert sum(x.num_rows for x in srv.dataset("ds")) == 8 * 500

    def test_abort_discards_and_is_idempotent(self):
        s = InMemoryFlightServer()
        c = FlightClient(s)
        stage(s, "ds", "t1", make_batches(2))
        assert txn_action(c, "txn-abort", "t1")["aborted"]
        assert "ds" not in s._store and stats_of(s)["staged_txns"] == 0
        again = txn_action(c, "txn-abort", "t1")
        assert again["aborted"] and again["duplicate"]
        assert stats_of(s)["txn_aborts"] == 1
        # unknown txn: no-op, not an error (coordinator aborts broadly)
        assert txn_action(c, "txn-abort", "never-staged")["aborted"] is False

    def test_commit_after_abort_and_abort_after_commit_are_errors(self):
        s = InMemoryFlightServer()
        c = FlightClient(s)
        stage(s, "ds", "t1", make_batches(1))
        txn_action(c, "txn-abort", "t1")
        with pytest.raises(FlightInvalidArgument):
            txn_action(c, "txn-commit", "t1")
        stage(s, "ds", "t2", make_batches(1))
        txn_action(c, "txn-commit", "t2")
        with pytest.raises(FlightInvalidArgument):
            txn_action(c, "txn-abort", "t2")
        # staging into a finished txn is also refused
        with pytest.raises(FlightInvalidArgument):
            stage(s, "ds", "t2", make_batches(1, seed=3))

    def test_commit_of_unknown_txn_is_not_found(self):
        with pytest.raises(FlightNotFound):
            txn_action(FlightClient(InMemoryFlightServer()), "txn-commit", "ghost")

    def test_commit_phase_rejected_on_the_doput_leg(self):
        s = InMemoryFlightServer()
        c = FlightClient(s)
        w = c.do_put(FlightDescriptor.for_command(
            StagedPutCommand("ds", "t1", "commit")), make_batches(1)[0].schema)
        with pytest.raises(FlightInvalidArgument):
            w.close()  # in-proc DoPut dispatches on close

    def test_schema_mismatch_across_staged_streams_rejected(self):
        s = InMemoryFlightServer()
        stage(s, "ds", "t1", make_batches(1))
        other = [RecordBatch.from_numpy({"z": np.arange(4, dtype=np.int64)})]
        with pytest.raises(FlightInvalidArgument):
            stage(s, "ds", "t1", other)


class TestStageGC:
    def test_expired_stage_is_reaped_and_commit_fails(self):
        s = InMemoryFlightServer(stage_ttl=0.15)
        c = FlightClient(s)
        stage(s, "ds", "t1", make_batches(2))  # the "crashed writer"
        deadline = time.time() + 5.0
        while stats_of(s)["staged_txns"] and time.time() < deadline:
            time.sleep(0.05)
        st = stats_of(s)
        assert st["staged_txns"] == 0 and st["staged_bytes"] == 0
        assert st["txn_gc_reaped"] == 1
        assert "ds" not in s._store  # never became readable
        with pytest.raises(FlightNotFound):
            txn_action(c, "txn-commit", "t1")

    def test_prepared_stage_is_pinned_against_gc(self):
        """After a yes vote the coordinator owns the txn's fate: the reaper
        must not fire between a sibling shard's commit and ours (that would
        leave the txn half-visible across shards)."""
        s = InMemoryFlightServer(stage_ttl=0.1)
        c = FlightClient(s)
        stage(s, "ds", "t1", make_batches(2))
        txn_action(c, "txn-prepare", "t1")
        time.sleep(0.35)  # several reaper intervals past the TTL
        s._gc_staged()
        assert stats_of(s)["staged_txns"] == 1  # pinned, not reaped
        txn_action(c, "txn-commit", "t1")      # the delayed commit still lands
        assert len(s.dataset("ds")) == 2
        # an explicit abort resolves an in-doubt prepared stage too
        stage(s, "ds", "t2", make_batches(1))
        txn_action(c, "txn-prepare", "t2")
        assert txn_action(c, "txn-abort", "t2")["aborted"]

    def test_live_stage_survives_the_reaper(self):
        s = InMemoryFlightServer(stage_ttl=30.0)
        stage(s, "ds", "t1", make_batches(1))
        s._gc_staged()
        assert stats_of(s)["staged_txns"] == 1
        txn_action(FlightClient(s), "txn-commit", "t1")
        assert len(s.dataset("ds")) == 1


# --------------------------------------------------------------------------
# commit racing concurrent readers
# --------------------------------------------------------------------------


class TestCommitVisibilityRace:
    def test_reader_never_sees_half_visible_txn(self):
        """Hammer DoGet while commits flip — every read sees a whole number
        of transactions (the per-shard flip is atomic under the store lock)."""
        s = InMemoryFlightServer(cache_encoded=False)
        c = FlightClient(s)
        s.add_dataset("ds", make_batches(1, rows=10))
        txn_rows = 4 * 100  # each txn stages 4 batches of 100 rows
        valid = {10 + i * txn_rows for i in range(21)}
        seen, bad, stop = set(), [], threading.Event()

        def reader():
            while not stop.is_set():
                try:
                    info = c.get_flight_info(FlightDescriptor.for_path("ds"))
                    n = sum(sum(b.num_rows for b in c.do_get(e.ticket))
                            for e in info.endpoints)
                except FlightNotFound:
                    continue
                seen.add(n)
                if n not in valid:
                    bad.append(n)

        threads = [threading.Thread(target=reader) for _ in range(3)]
        for t in threads:
            t.start()
        for i in range(20):
            stage(s, "ds", f"t{i}", make_batches(4, rows=100, seed=i))
            txn_action(c, "txn-commit", f"t{i}")
        stop.set()
        for t in threads:
            t.join()
        assert not bad, f"torn reads: {sorted(set(bad))}; valid={sorted(valid)}"
        assert len(seen) > 1  # the race actually observed multiple states


# --------------------------------------------------------------------------
# cluster coordination
# --------------------------------------------------------------------------


class TestClusterTransactions:
    def test_transactional_write_all_or_nothing_visible(self):
        cl = FlightClusterServer(num_shards=4)
        cc = FlightClusterClient(cl)
        batches = make_batches(8)
        cc.write("events", batches, transactional=True)
        table, _ = cc.read("events")
        assert table.num_rows == sum(b.num_rows for b in batches)
        for shard in cl.shards:
            st = stats_of(shard)
            assert st["staged_txns"] == 0 and st["txn_commits"] == 1

    def test_transactional_write_over_tcp(self):
        cl = FlightClusterServer(num_shards=3).serve_tcp()
        try:
            cc = FlightClusterClient(f"tcp://127.0.0.1:{cl.port}")
            batches = make_batches(6, seed=4)
            cc.write("ev", batches, transactional=True)
            table, _ = cc.read("ev")
            assert table.num_rows == sum(b.num_rows for b in batches)
        finally:
            cl.shutdown()

    def test_abort_after_partial_stage_nothing_visible(self):
        """A writer that staged only some shards (then crashed): the commit
        round's prepare vote fails, every shard's stage is aborted."""
        cl = FlightClusterServer(num_shards=4)
        head = FlightClient(cl)
        # stage on shards 0 and 1 only — the crash happened before 2 and 3
        stage(cl.shards[0], "ds", "t1", make_batches(2))
        stage(cl.shards[1], "ds", "t1", make_batches(2, seed=1))
        with pytest.raises(FlightUnavailable) as ei:
            txn_action(head, "txn-commit", "t1", expect_shards=[0, 1, 2, 3])
        assert ei.value.detail["missing_shards"] == [2, 3]
        for shard in cl.shards:
            assert "ds" not in shard._store  # all-or-none: none
            assert stats_of(shard)["staged_txns"] == 0  # aborted, not lingering
        assert stats_of(cl.shards[0])["txn_aborts"] == 1

    def test_commit_aborts_when_one_shards_stage_was_gcd(self):
        """Even without expect_shards, a stage the reaper ate on one shard
        must abort the whole txn — committing the survivors would tear it."""
        cl = FlightClusterServer(num_shards=2)
        head = FlightClient(cl)
        stage(cl.shards[0], "ds", "t1", make_batches(2))
        stage(cl.shards[1], "ds", "t1", make_batches(2, seed=1))
        cl.shards[1]._staged["t1"].expires_at = 0.0  # writer paused > TTL
        cl.shards[1]._gc_staged()
        with pytest.raises(FlightUnavailable) as ei:
            txn_action(head, "txn-commit", "t1")  # note: no expect_shards
        assert ei.value.detail["expired_shards"] == [1]
        assert all("ds" not in s._store for s in cl.shards)
        assert stats_of(cl.shards[0])["staged_txns"] == 0  # aborted everywhere

    def test_commit_without_expectations_commits_staged_shards(self):
        cl = FlightClusterServer(num_shards=3)
        head = FlightClient(cl)
        stage(cl.shards[0], "ds", "t1", make_batches(2))
        stage(cl.shards[2], "ds", "t1", make_batches(2, seed=1))
        ack = txn_action(head, "txn-commit", "t1")
        assert ack["shards"] == [0, 2] and ack["batches"] == 4
        # the head learned the dataset: reads fan in the committed shards
        table, _ = FlightClusterClient(cl).read("ds")
        assert table.num_rows == 4 * 500

    def test_duplicate_cluster_commit_round_is_idempotent(self):
        cl = FlightClusterServer(num_shards=2)
        cc = FlightClusterClient(cl)
        head = FlightClient(cl)
        cc.write("ds", make_batches(4), transactional=True, txn_id="t-dup")
        before = sum(b.num_rows for b in cl.dataset("ds"))
        ack = txn_action(head, "txn-commit", "t-dup")  # retried coordinator round
        assert ack["committed"] and ack["duplicate"]
        assert sum(b.num_rows for b in cl.dataset("ds")) == before

    def test_cluster_abort_fans_out(self):
        cl = FlightClusterServer(num_shards=3)
        head = FlightClient(cl)
        for i in range(3):
            stage(cl.shards[i], "ds", "t1", make_batches(1, seed=i))
        out = txn_action(head, "txn-abort", "t1")
        assert out["aborted"] and out["shards"] == [0, 1, 2]
        assert all(stats_of(s)["staged_txns"] == 0 for s in cl.shards)

    def test_head_funneled_staged_put_partitions_and_stages(self):
        """Legacy single-stream writers can stage through the head too."""
        cl = FlightClusterServer(num_shards=2)
        head = FlightClient(cl)
        batches = make_batches(4)
        desc = FlightDescriptor.for_command(StagedPutCommand("ds", "t1", "stage"))
        w = head.do_put(desc, batches[0].schema)
        for b in batches:
            w.write_batch(b)
        ack = w.close()
        assert ack["staged"] and ack["batches"] == 4
        assert all("ds" not in s._store for s in cl.shards)
        txn_action(head, "txn-commit", "t1")
        assert sum(b.num_rows for b in cl.dataset("ds")) == 4 * 500

    def test_single_server_write_parallel_transactional(self):
        srv = InMemoryFlightServer().serve_tcp()
        try:
            c = FlightClient(f"tcp://127.0.0.1:{srv.port}")
            batches = make_batches(8, seed=5)
            c.write_parallel(FlightDescriptor.for_path("ds"), batches,
                             max_streams=4, transactional=True)
            assert sum(b.num_rows for b in srv.dataset("ds")) == 8 * 500
            assert stats_of(srv)["txn_commits"] == 1
            # txn verbs show up in the per-action metrics breakdown
            assert stats_of(srv)["verbs"]["actions"]["txn-commit"] == 1
        finally:
            srv.shutdown()

    def test_transactional_matches_plain_write_content(self):
        batches = make_batches(6, seed=7)
        plain = FlightClusterServer(num_shards=3)
        FlightClusterClient(plain).write("ds", batches)
        txn = FlightClusterServer(num_shards=3)
        FlightClusterClient(txn).write("ds", batches, transactional=True)
        def rows(cl):
            return sorted(r for b in cl.dataset("ds") for r in b.to_rows())
        assert rows(plain) == rows(txn)

"""Distributed runtime: sharding rules, checkpoint/reshard, fault, elastic,
compressed ring collective (multi-device via subprocess)."""
import json
import subprocess
import sys
import tempfile
import textwrap
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.distributed.checkpoint import CheckpointManager
from repro.distributed.elastic import best_mesh_shape, plan_reshape, repartition_tickets
from repro.distributed.fault import (
    FailureDetector,
    RestartPolicy,
    StragglerDetector,
    TrainSupervisor,
    WorkerState,
)
from repro.distributed.sharding import (
    DEFAULT_RULES,
    ShardingCtx,
    resolve_spec,
    single_device_ctx,
)


class TestShardingRules:
    def test_resolve_basic(self):
        ctx = single_device_ctx()
        mesh = ctx.mesh
        assert resolve_spec(("batch", "seq", "embed_nosplit"), mesh)[0] == "data"
        assert resolve_spec(("embed", "ff"), mesh) == jax.sharding.PartitionSpec("data", "model")

    def test_missing_axis_degrades_to_replication(self):
        ctx = single_device_ctx()  # no "pod" axis
        spec = resolve_spec(("batch",), ctx.mesh)
        assert spec[0] == "data"  # pod dropped, data kept

    def test_no_double_use_of_axis(self):
        ctx = single_device_ctx()
        spec = resolve_spec(("embed", "embed"), ctx.mesh)
        # second occurrence can't reuse "data"
        assert spec == jax.sharding.PartitionSpec("data")


class TestCheckpoint:
    def _state(self):
        return {"params": {"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8),
                           "b": jnp.ones((8,), jnp.bfloat16)},
                "opt": {"mu": jnp.zeros((8, 8))}, "step": jnp.int32(3)}

    def test_roundtrip(self, tmp_path):
        mgr = CheckpointManager(tmp_path)
        state = self._state()
        mgr.save(5, state)
        out = mgr.restore(5, state)
        for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(out)):
            np.testing.assert_allclose(np.asarray(a, np.float32), np.asarray(b, np.float32))

    def test_atomic_commit_ignores_partial(self, tmp_path):
        mgr = CheckpointManager(tmp_path)
        mgr.save(1, self._state())
        # simulate a crash mid-save: .tmp dir without manifest
        (tmp_path / "step_000000002.tmp").mkdir()
        (tmp_path / "step_000000003").mkdir()  # committed-looking but no manifest
        assert mgr.latest_step() == 1

    def test_async_save(self, tmp_path):
        mgr = CheckpointManager(tmp_path)
        mgr.save_async(7, self._state(), extra={"loader": {"epoch": 1, "cursor": 9}})
        mgr.wait()
        assert mgr.latest_step() == 7
        mani = json.loads((tmp_path / "step_000000007" / "manifest.json").read_text())
        assert mani["extra"]["loader"]["cursor"] == 9

    def test_gc_keeps_last_k(self, tmp_path):
        mgr = CheckpointManager(tmp_path, keep=2)
        for s in (1, 2, 3, 4):
            mgr.save(s, self._state())
        assert mgr.all_steps() == [3, 4]

    def test_restore_missing_leaf_raises(self, tmp_path):
        mgr = CheckpointManager(tmp_path)
        mgr.save(1, {"a": jnp.ones(3)})
        with pytest.raises(KeyError):
            mgr.restore(1, {"a": jnp.ones(3), "b": jnp.ones(3)})


class TestFault:
    def test_failure_detection(self):
        det = FailureDetector(timeout_s=0.2, suspect_s=0.05)
        det.register("w0")
        det.register("w1")
        det.heartbeat("w0")
        t0 = time.time()
        dead = det.sweep(now=t0 + 0.1)
        assert dead == [] and det.workers["w1"].state == WorkerState.SUSPECT
        dead = det.sweep(now=t0 + 0.3)
        assert set(dead) == {"w0", "w1"}
        det.heartbeat("w0")
        assert det.alive() == ["w0"]

    def test_straggler_flagging(self):
        s = StragglerDetector(factor=1.5, patience=2)
        flagged = []
        for step in range(3):  # flagged() evaluates once per step report round
            for w in ("a", "b", "c", "d"):
                s.report(w, 2.5 if w == "d" else 1.0)
            flagged = s.flagged()
        assert flagged == ["d"]
        # a recovered worker unflags
        for w in ("a", "b", "c", "d"):
            s.report(w, 1.0)
        assert s.flagged() == []

    def test_supervisor_restarts_from_checkpoint(self, tmp_path):
        mgr = CheckpointManager(tmp_path)
        mgr.save(10, {"x": jnp.ones(2)})
        calls = []

        def run(start):
            calls.append(start)
            if len(calls) == 1:
                raise RuntimeError("node died")
            return start + 5

        sup = TrainSupervisor(RestartPolicy(max_restarts=2, backoff_s=0.01), mgr,
                              logger=lambda m: None)
        assert sup.run(run) == 15
        assert calls == [10, 10]


class TestElastic:
    def test_best_mesh(self):
        assert best_mesh_shape(512) == (2, 16, 16)
        assert best_mesh_shape(300) == (1, 16, 16)
        assert best_mesh_shape(255) == (1, 8, 16)
        assert best_mesh_shape(1) == (1, 1, 1)

    def test_plan_keeps_global_batch(self):
        ch = plan_reshape(512, 256, keep_global_batch=True)
        assert ch.mesh_shape == (1, 16, 16) and ch.microbatch_scale == 2

    def test_ticket_repartition(self):
        a = repartition_tickets(10, ["h0", "h1", "h2"])
        assert sorted(sum(a.values(), [])) == list(range(10))
        assert max(map(len, a.values())) - min(map(len, a.values())) <= 1


REPO = Path(__file__).resolve().parents[1]


@pytest.mark.slow
def test_compressed_ring_allreduce_multidevice():
    """int8 ring psum ≈ exact psum on an 8-device host mesh (subprocess —
    device count is locked at first jax init, so this can't run in-process)."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
        from jax.experimental.shard_map import shard_map
        from jax.sharding import Mesh, PartitionSpec as P
        from repro.distributed.collectives import compressed_psum_ring
        mesh = Mesh(np.array(jax.devices()).reshape(8), ("data",))
        x = jnp.asarray(np.random.default_rng(0).standard_normal((8, 4096)), jnp.float32)
        def ring(xl):
            return compressed_psum_ring(xl.reshape(-1), "data")
        def exact(xl):
            return jax.lax.psum(xl.reshape(-1), "data")
        with mesh:
            r = shard_map(ring, mesh=mesh, in_specs=P("data"), out_specs=P("data"), check_rep=False)(x)
            e = shard_map(exact, mesh=mesh, in_specs=P("data"), out_specs=P("data"), check_rep=False)(x)
        r, e = np.asarray(r), np.asarray(e)
        rel = np.abs(r - e).max() / (np.abs(e).max() + 1e-9)
        assert rel < 0.02, rel
        print("REL_ERR", rel)
    """)
    proc = subprocess.run([sys.executable, "-c", code], capture_output=True, text=True,
                          env={"PYTHONPATH": str(REPO / "src"), "PATH": "/usr/bin:/bin"},
                          timeout=600)
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "REL_ERR" in proc.stdout

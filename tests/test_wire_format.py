"""Wire data plane: binary IPC metadata, encode cache, coalescing, pooling.

Covers PR 2's hot-path overhaul: golden bytes pin the binary metadata
layout; property tests sweep nested/sliced/nullable columns through both
metadata codecs; transport tests assert the syscall-shape (coalesced
sendmsg, IOV_MAX chunking, pooled receive slabs) and the server's
encode-once cache counters.
"""
import json
import socket
import threading
import time

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import RecordBatch, read_stream, write_stream
from repro.core.buffer import BufferPool
from repro.core.ipc import (
    BIN_HEADER,
    CODEC_BINARY,
    CODEC_JSON,
    META_MAGIC,
    BatchMeta,
    decode_message,
    encode_batch,
    encode_eos,
    encode_schema,
    parse_metadata,
)
from repro.core.flight import FlightClient, FlightDescriptor, InMemoryFlightServer
from repro.core.flight import transport as transport_mod
from repro.core.flight.transport import FrameConnection, KIND_DATA, SocketListener


def conn_pair() -> tuple[FrameConnection, FrameConnection]:
    a, b = socket.socketpair()
    return FrameConnection(a), FrameConnection(b)


# ---------------------------------------------------------------------------
# binary metadata layout
# ---------------------------------------------------------------------------


class TestBinaryMetadata:
    def test_golden_bytes(self):
        """Pin the binary metadata layout for {"x": [1, None, 3]} (int64).

        header <BBHIIQQ>: magic=0xB1, kind=1(batch), reserved, n_nodes=1,
        n_buffers=2, rows=3, body_len=128; one node <QB> (len=3, flags=1:
        validity present); two buffer placements <QQ>: validity (0, 1) and
        values (64, 24).  Changing any of this is a wire-format break."""
        meta = encode_batch(RecordBatch.from_pydict({"x": [1, None, 3]}), CODEC_BINARY).metadata
        golden = (
            "b1010000"          # magic, kind, reserved
            "01000000" "02000000"  # n_nodes, n_buffers
            "0300000000000000"  # rows
            "8000000000000000"  # body_len = 128
            "0300000000000000" "01"  # node: length=3, flags=validity
            "0000000000000000" "0100000000000000"  # validity @0, 1 B
            "4000000000000000" "1800000000000000"  # values @64, 24 B
        )
        assert meta.hex() == golden

    def test_first_byte_discriminates_codecs(self):
        b = RecordBatch.from_pydict({"x": [1.0, 2.0]})
        assert encode_batch(b, CODEC_BINARY).metadata[0] == META_MAGIC
        assert encode_batch(b, CODEC_JSON).metadata[0:1] == b"{"
        assert encode_schema(b.schema).metadata[0:1] == b"{"  # schema stays JSON

    def test_parse_roundtrip_both_codecs(self):
        b = RecordBatch.from_pydict({"s": ["aa", None, "c"], "v": [[1], [2, 3], None]})
        for codec in (CODEC_BINARY, CODEC_JSON):
            meta = parse_metadata(encode_batch(b, codec).metadata)
            assert isinstance(meta, BatchMeta)
            assert meta.rows == 3
        bin_meta = parse_metadata(encode_batch(b, CODEC_BINARY).metadata)
        json_meta = parse_metadata(encode_batch(b, CODEC_JSON).metadata)
        assert bin_meta.nodes == json_meta.nodes
        assert bin_meta.buffers == json_meta.buffers
        assert bin_meta.body_len == json_meta.body_len

    def test_eos_both_codecs(self):
        for codec in (CODEC_BINARY, CODEC_JSON):
            msg = decode_message(parse_metadata(encode_eos(codec).metadata), None)
            assert msg.kind == "eos"
        assert len(encode_eos(CODEC_BINARY).metadata) == BIN_HEADER.size

    def test_binary_metadata_is_padding_tolerant(self):
        # frame_parts zero-pads metadata to 8B; the parser must ignore the tail
        b = RecordBatch.from_pydict({"x": [1, 2]})
        meta = encode_batch(b, CODEC_BINARY).metadata + b"\0" * 7
        parsed = parse_metadata(meta)
        assert parsed.rows == 2


# ---------------------------------------------------------------------------
# property tests: IPC round-trips over both codecs
# ---------------------------------------------------------------------------

pyint = st.one_of(st.none(), st.integers(-(2**40), 2**40))
pystr = st.one_of(st.none(), st.text(max_size=8))
pylist = st.one_of(st.none(), st.lists(st.integers(-100, 100), max_size=4))
codecs = st.sampled_from([CODEC_BINARY, CODEC_JSON])


@settings(max_examples=30, deadline=None)
@given(st.lists(pyint, min_size=1, max_size=40), codecs)
def test_prop_int_nulls_roundtrip(values, codec):
    b = RecordBatch.from_pydict({"c": values})
    assert read_stream(write_stream([b], codec=codec))[0].to_pydict()["c"] == values


@settings(max_examples=30, deadline=None)
@given(st.lists(pystr, min_size=1, max_size=40), codecs)
def test_prop_utf8_nulls_roundtrip(values, codec):
    b = RecordBatch.from_pydict({"c": values})
    assert read_stream(write_stream([b], codec=codec))[0].to_pydict()["c"] == values


@settings(max_examples=30, deadline=None)
@given(st.lists(pylist, min_size=1, max_size=20), codecs)
def test_prop_list_nulls_roundtrip(values, codec):
    b = RecordBatch.from_pydict({"c": values})
    assert read_stream(write_stream([b], codec=codec))[0].to_pydict()["c"] == values


@settings(max_examples=25, deadline=None)
@given(
    st.lists(st.one_of(pyint, st.none()), min_size=2, max_size=40),
    st.lists(pystr, min_size=2, max_size=40),
    codecs,
    st.data(),
)
def test_prop_sliced_batch_roundtrip(ints, strs, codec, data):
    n = min(len(ints), len(strs))
    b = RecordBatch.from_pydict({"i": ints[:n], "s": strs[:n]})
    lo = data.draw(st.integers(0, n - 1))
    hi = data.draw(st.integers(lo + 1, n))
    out = read_stream(write_stream([b.slice(lo, hi - lo)], codec=codec))[0]
    assert out.to_pydict() == {"i": ints[:n][lo:hi], "s": strs[:n][lo:hi]}


@settings(max_examples=20, deadline=None)
@given(st.lists(st.one_of(st.none(), st.lists(pystr, max_size=3)), min_size=1, max_size=12), codecs)
def test_prop_nested_list_of_utf8_roundtrip(values, codec):
    from repro.core import Array, Schema
    from repro.core.schema import Field, list_, utf8

    # type inference can't see list<utf8> in all-None/empty shells: pin it
    arr = Array.from_pylist(values, list_(utf8))
    batch = RecordBatch(Schema((Field("c", list_(utf8)),)), [arr])
    out = read_stream(write_stream([batch], codec=codec))[0]
    assert out.to_pydict()["c"] == values


# ---------------------------------------------------------------------------
# pooled receive allocator
# ---------------------------------------------------------------------------


class TestBufferPool:
    def test_recycles_released_slab(self):
        pool = BufferPool()
        n = 48 << 10  # more than half the min slab: no two fit side by side
        b1 = pool.acquire(n)
        base1 = b1.data.base.ctypes.data
        del b1
        b2 = pool.acquire(n)  # can't bump-carve: must scan and recycle
        assert b2.data.base.ctypes.data == base1
        assert pool.hits == 1 and pool.misses == 1

    def test_live_carves_never_overlap(self):
        pool = BufferPool()
        b1 = pool.acquire(100)
        b1.data[:] = 7
        b2 = pool.acquire(100)  # bump-carved beside b1, never over it
        b2.data[:] = 9
        assert (b1.data == 7).all()
        assert (b2.data == 9).all()
        assert pool.hits == 1 and pool.misses == 1  # shared slab, no new alloc

    def test_never_restarts_pinned_slab(self):
        pool = BufferPool()
        b1 = pool.acquire(256)
        b1.data[:] = 42
        view = b1.slice(10, 20)  # survives the parent Buffer
        del b1
        # too big to bump-carve: must scan — and the pinned slab is not free
        b3 = pool.acquire(BufferPool.MIN_SLAB)
        assert pool.misses == 2
        assert b3.data.base is not view.data.base
        assert (view.data == 42).all()

    def test_alignment(self):
        pool = BufferPool()
        for n in (1, 63, 4096, 1 << 20):
            assert pool.acquire(n).is_aligned

    def test_decoded_batch_survives_pool_pressure(self):
        # decode a frame from a pooled body, hammer the pool, re-check data
        server, client = conn_pair()
        batch = RecordBatch.from_numpy({"x": np.arange(4096, dtype=np.int64)})
        server.send_data(encode_schema(batch.schema))
        server.send_data(encode_batch(batch))
        _, meta, _ = client.recv_frame()
        schema = decode_message(meta, None).schema
        _, meta, body = client.recv_frame()
        decoded = decode_message(meta, body).batch(schema)
        del body
        for _ in range(8):
            client.pool.acquire(64 << 10)
        assert decoded == batch
        server.close(), client.close()


# ---------------------------------------------------------------------------
# transport: coalescing + IOV chunking + buffered receive
# ---------------------------------------------------------------------------


class TestCoalescing:
    def test_many_small_frames_one_sendmsg(self):
        server, client = conn_pair()
        batches = [RecordBatch.from_numpy({"x": np.arange(8, dtype=np.int64) + i})
                   for i in range(64)]
        msgs = [encode_batch(b) for b in batches]
        server.send_data_many(msgs)
        assert server.sendmsg_calls < len(msgs) / 4  # coalesced, not per-frame
        schema = batches[0].schema
        for want in batches:
            kind, meta, body = client.recv_frame()
            assert kind == KIND_DATA
            assert decode_message(meta, body).batch(schema) == want
        server.close(), client.close()

    def test_budget_flushes(self):
        server, client = conn_pair()
        rows = 64 << 10  # 512 KiB per batch → budget forces multiple flushes
        msgs = [encode_batch(RecordBatch.from_numpy({"x": np.arange(rows, dtype=np.int64)}))
                for _ in range(8)]
        got = []

        def drain():
            for _ in range(len(msgs)):
                got.append(client.recv_frame()[2].nbytes)

        t = threading.Thread(target=drain)
        t.start()
        server.send_data_many(msgs, budget=1 << 20)
        t.join(10)
        assert got == [rows * 8] * 8
        assert server.sendmsg_calls >= 4  # not one giant flush
        server.close(), client.close()

    def test_iov_max_chunking(self, monkeypatch):
        # wide batch: every column is two iovecs (values + pad) — with a tiny
        # IOV_MAX the single frame must be split across sendmsg calls
        monkeypatch.setattr(transport_mod, "IOV_MAX", 4)
        server, client = conn_pair()
        wide = RecordBatch.from_numpy(
            {f"c{i}": np.arange(3, dtype=np.int64) for i in range(40)})
        server.send_data(encode_batch(wide))
        assert server.sendmsg_calls > 1
        kind, meta, body = client.recv_frame()
        assert decode_message(meta, body).batch(wide.schema) == wide
        server.close(), client.close()

    def test_interleaved_ctrl_and_data(self):
        server, client = conn_pair()
        b = RecordBatch.from_pydict({"x": [1, 2, 3]})
        server.send_ctrl({"ok": True})
        server.send_data_many([encode_batch(b)] * 3)
        server.send_ctrl({"done": True})
        assert client.recv_ctrl() == {"ok": True}
        for _ in range(3):
            kind, meta, body = client.recv_frame()
            assert decode_message(meta, body).batch(b.schema) == b
        assert client.recv_ctrl() == {"done": True}
        server.close(), client.close()


# ---------------------------------------------------------------------------
# server encode-once cache
# ---------------------------------------------------------------------------


def make_batches(n=4, rows=64, seed=0):
    rng = np.random.default_rng(seed)
    return [RecordBatch.from_numpy({"a": rng.integers(0, 100, rows).astype(np.int64)})
            for _ in range(n)]


class TestEncodeCache:
    def server_stats(self, client):
        return json.loads(client.do_action("server-stats")[0].body)

    def test_cached_do_get_encodes_zero_times(self):
        srv = InMemoryFlightServer().serve_tcp()
        try:
            srv.add_dataset("ds", make_batches(4))
            c = FlightClient(f"tcp://127.0.0.1:{srv.port}")
            info = c.get_flight_info(FlightDescriptor.for_path("ds"))
            c.do_get(info.endpoints[0].ticket).read_all()  # warm: builds cache
            warm = self.server_stats(c)["encode_calls"]
            assert warm == 4
            for _ in range(3):
                c.do_get(info.endpoints[0].ticket).read_all()
            stats = self.server_stats(c)
            assert stats["encode_calls"] == warm  # zero encode_batch since warm
            assert stats["encode_cache_hits"] == 3
        finally:
            srv.shutdown()

    def test_do_put_invalidates(self):
        srv = InMemoryFlightServer().serve_tcp()
        try:
            srv.add_dataset("ds", make_batches(2))
            c = FlightClient(f"tcp://127.0.0.1:{srv.port}")
            info = c.get_flight_info(FlightDescriptor.for_path("ds"))
            t = info.endpoints[0].ticket
            first = c.do_get(t).read_all().combine()
            extra = make_batches(1, seed=9)
            w = c.do_put(FlightDescriptor.for_path("ds"), extra[0].schema)
            w.write_batches(extra)
            w.close()
            got = c.do_get(FlightClient(srv).get_flight_info(
                FlightDescriptor.for_path("ds")).endpoints[0].ticket).read_all()
            assert got.num_rows == first.num_rows + 64  # fresh bytes, not stale cache
            assert self.server_stats(c)["encode_calls"] == 2 + 3
        finally:
            srv.shutdown()

    def test_override_bypasses_cache(self):
        srv = InMemoryFlightServer().serve_tcp()
        try:
            srv.add_dataset("ds", make_batches(2))
            seen = {"n": 0}
            orig = srv.do_get_impl

            def counting(ticket):
                seen["n"] += 1
                return orig(ticket)

            srv.do_get_impl = counting  # instance patch must keep being served
            c = FlightClient(f"tcp://127.0.0.1:{srv.port}")
            info = c.get_flight_info(FlightDescriptor.for_path("ds"))
            c.do_get(info.endpoints[0].ticket).read_all()
            c.do_get(info.endpoints[0].ticket).read_all()
            assert seen["n"] == 2
            assert self.server_stats(c)["encode_cache_misses"] == 0
        finally:
            srv.shutdown()

    def test_uncoalesced_json_server_still_serves(self):
        srv = InMemoryFlightServer(wire_codec=CODEC_JSON, coalesce=False,
                                   cache_encoded=False).serve_tcp()
        try:
            batches = make_batches(3)
            srv.add_dataset("ds", batches)
            c = FlightClient(f"tcp://127.0.0.1:{srv.port}")
            info = c.get_flight_info(FlightDescriptor.for_path("ds"))
            got = c.do_get(info.endpoints[0].ticket).read_all()
            assert got.num_rows == sum(b.num_rows for b in batches)
            assert self.server_stats(c)["encode_cache_misses"] == 0
        finally:
            srv.shutdown()


# ---------------------------------------------------------------------------
# listener thread reaping
# ---------------------------------------------------------------------------


class TestListenerReap:
    def test_finished_handlers_are_reaped(self):
        done = threading.Event()

        def handler(conn):
            conn.recv_frame()

        lst = SocketListener(handler).start()
        try:
            for _ in range(12):
                s = socket.create_connection((lst.host, lst.port))
                s.close()
            # one more connection triggers the reap of the dead dozen
            deadline = time.time() + 5
            while time.time() < deadline:
                s = socket.create_connection((lst.host, lst.port))
                s.close()
                time.sleep(0.05)
                if len(lst._threads) <= 3:
                    break
            assert len(lst._threads) <= 3  # not one Thread per connection ever
        finally:
            lst.stop()

"""Distributed query phase 2: grouped partial aggregation + shuffle joins.

Property-based equivalence suite: every distributed result (grouped
aggregation over 1/2/4 shards, both placements, R=2 replication, shuffled
equi-joins, replica death mid-query) must be element-equal to the
single-node ``query.engine`` oracle run over the same rows.  Structure
(row count, group cardinality, key dtype, shard count, placement) is drawn
by hypothesis; bulk values come from a numpy generator seeded by a drawn
seed, so the suite runs identically under ``tests/_hypothesis_stub.py``.

Equality contract: group keys, counts, integer sums and extrema compare
exactly; float sums/means compare within 1e-9 relative (distributed merge
adds partial sums in a different order than the single-pass oracle).
"""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import RecordBatch
from repro.core.flight import (
    FaultInjector,
    FlightClusterClient,
    FlightClusterServer,
)
from repro.query import (
    QueryPlan,
    aggregate,
    col,
    hash_join,
    merge_partials,
    partial_aggregate,
    partial_schema,
)

AGGS = [("sum", "v"), ("mean", "v"), ("min", "i"), ("max", "i"), ("count", "v")]


def build_table(kind: str, n: int, card: int, masked: bool, seed: int):
    """One logical table: group key column ``g`` (dtype ``kind``, ``card``
    distinct values), float values ``v``, int values ``i`` — plus a ragged
    batch split (including zero-row batches) of the same rows."""
    rng = np.random.default_rng(seed)
    gidx = rng.integers(0, card, n)
    if kind == "int64":
        g = (gidx.astype(np.int64) * 3) - card
    elif kind == "float64":
        pool = np.arange(card) * 0.75 - 1.0
        pool[0] = -0.0  # -0.0 / 0.0 must canonicalize to one group
        g = pool[gidx]
    else:  # utf8, optionally with a null group (masked varlen keys)
        pool = [f"key-{j}" for j in range(card)]
        if masked:
            pool[0] = None
        g = [pool[j] for j in gidx]
    data = {
        "g": g,
        "v": rng.normal(scale=100.0, size=n),
        "i": rng.integers(-(10**6), 10**6, n).astype(np.int64),
    }
    whole = RecordBatch.from_pydict(data)
    cuts = sorted(int(c) for c in rng.integers(0, n + 1, 3))
    bounds = [0, *cuts, n]
    batches = [whole.slice(a, b - a) for a, b in zip(bounds, bounds[1:])]
    return whole, batches


def scalar_eq(a, b) -> bool:
    if isinstance(a, float) and isinstance(b, float) and a != a and b != b:
        return True  # NaN key/result == NaN key/result
    return a == b


def assert_grouped_equal(oracle: RecordBatch, got: RecordBatch) -> None:
    od, gd = oracle.to_pydict(), got.to_pydict()
    assert list(od) == list(gd)
    assert got.num_rows == oracle.num_rows
    for name in od:
        if name.startswith(("sum(", "mean(")):
            np.testing.assert_allclose(gd[name], od[name], rtol=1e-9, atol=1e-12)
        else:  # keys, counts, integer extrema: exact
            assert all(scalar_eq(o, g) for o, g in zip(od[name], gd[name])), name


def assert_scalars_equal(oracle: dict, got: dict) -> None:
    assert set(oracle) == set(got)
    for k in oracle:
        if k.startswith(("sum(", "mean(")):
            np.testing.assert_allclose(got[k], oracle[k], rtol=1e-9, atol=1e-12)
        else:
            assert scalar_eq(oracle[k], got[k]), k


def make_cluster(shards: int, scheme: str, replicas: int = 1) -> FlightClusterServer:
    kw = {"hash_key": "g"} if scheme == "hash" else {}
    return FlightClusterServer(num_shards=shards, placement=scheme,
                               replicas=replicas, **kw)


# --------------------------------------------------------------------------
# property: distributed grouped aggregation == single-node oracle
# --------------------------------------------------------------------------


@settings(max_examples=15, deadline=None)
@given(st.data())
def test_prop_grouped_aggregation_distributed_equals_oracle(data):
    n = data.draw(st.integers(1, 120))
    card = data.draw(st.integers(1, n))  # 1 group .. one group per row
    kind = data.draw(st.sampled_from(["int64", "float64", "utf8"]))
    masked = data.draw(st.booleans())
    shards = data.draw(st.sampled_from([1, 2, 4]))
    scheme = data.draw(st.sampled_from(["round_robin", "hash"]))
    filtered = data.draw(st.booleans())
    seed = data.draw(st.integers(0, 2**31 - 1))
    whole, batches = build_table(kind, n, card, masked, seed)
    plan = QueryPlan("t", aggregations=AGGS, group_by=["g"],
                     predicate=(col("v") > 0.0) if filtered else None)
    cl = make_cluster(shards, scheme)
    try:
        cl.add_dataset("t", batches)
        got, _ = FlightClusterClient(cl).aggregate(plan)
        assert_grouped_equal(aggregate(plan, [whole]), got)
    finally:
        cl.shutdown()


@settings(max_examples=8, deadline=None)
@given(st.data())
def test_prop_grouped_aggregation_replicated_equals_oracle(data):
    n = data.draw(st.integers(1, 100))
    card = data.draw(st.integers(1, n))
    kind = data.draw(st.sampled_from(["int64", "utf8"]))
    seed = data.draw(st.integers(0, 2**31 - 1))
    whole, batches = build_table(kind, n, card, masked=False, seed=seed)
    plan = QueryPlan("t", aggregations=AGGS, group_by=["g"])
    cl = make_cluster(shards=3, scheme="round_robin", replicas=2)
    try:
        cl.add_dataset("t", batches)
        got, _ = FlightClusterClient(cl).aggregate(plan)
        assert_grouped_equal(aggregate(plan, [whole]), got)
    finally:
        cl.shutdown()


@settings(max_examples=10, deadline=None)
@given(st.data())
def test_prop_ungrouped_scalars_distributed_equals_oracle(data):
    n = data.draw(st.integers(1, 120))
    shards = data.draw(st.sampled_from([1, 2, 4]))
    scheme = data.draw(st.sampled_from(["round_robin", "hash"]))
    threshold = data.draw(st.floats(-150.0, 150.0))
    seed = data.draw(st.integers(0, 2**31 - 1))
    whole, batches = build_table("int64", n, max(1, n // 3), False, seed)
    # the threshold can empty every shard — the (sum, count) state must
    # still merge to count 0 / NaN mean, never poison other shards
    plan = QueryPlan("t", aggregations=AGGS, predicate=col("v") > threshold)
    cl = make_cluster(shards, scheme)
    try:
        cl.add_dataset("t", batches)
        got, _ = FlightClusterClient(cl).aggregate(plan)
        assert isinstance(got, dict)
        assert_scalars_equal(aggregate(plan, [whole]), got)
    finally:
        cl.shutdown()


# --------------------------------------------------------------------------
# property: shuffled equi-join == single-node hash_join oracle
# --------------------------------------------------------------------------


def _row_set(batches, names):
    return sorted(
        tuple(row) for b in batches
        for row in zip(*[b.to_pydict()[c] for c in names])
    )


@settings(max_examples=10, deadline=None)
@given(st.data())
def test_prop_shuffle_join_distributed_equals_oracle(data):
    n_l = data.draw(st.integers(1, 80))
    n_r = data.draw(st.integers(1, 80))
    card = data.draw(st.integers(1, 25))
    kind = data.draw(st.sampled_from(["int64", "utf8"]))
    shards = data.draw(st.sampled_from([2, 4]))
    replicas = data.draw(st.sampled_from([1, 2]))
    seed = data.draw(st.integers(0, 2**31 - 1))
    rng = np.random.default_rng(seed)

    def side(m, vname):
        gidx = rng.integers(0, card, m)
        if kind == "int64":
            k = gidx.astype(np.int64) * 2
        else:
            pool = [f"j{j}" for j in range(card)]
            k = [pool[j] for j in gidx]
        d = {"k": k, vname: rng.normal(size=m)}
        whole = RecordBatch.from_pydict(d)
        cut = int(rng.integers(0, m + 1))
        return whole, [whole.slice(0, cut), whole.slice(cut)]

    lw, lb = side(n_l, "x")
    rw, rb = side(n_r, "y")
    oracle = hash_join([lw], [rw], ["k"])
    cl = FlightClusterServer(num_shards=shards, replicas=replicas)
    try:
        cl.add_dataset("L", lb)
        cl.add_dataset("R", rb)
        cc = FlightClusterClient(cl)
        table, _ = cc.join("L", "R", "k", "J")
        assert [f.name for f in oracle.schema.fields] == ["k", "x", "y"]
        assert _row_set(table.batches, ["k", "x", "y"]) == \
               _row_set([oracle], ["k", "x", "y"])
    finally:
        cl.shutdown()


# --------------------------------------------------------------------------
# partial/final mean regression (the concat-then-average bug)
# --------------------------------------------------------------------------


class TestPartialFinalContract:
    def test_mean_state_is_sum_count_pair(self):
        whole, _ = build_table("int64", 50, 5, False, seed=3)
        plan = QueryPlan("t", aggregations=[("mean", "v")], group_by=["g"])
        ps = partial_schema(plan, whole.schema)
        assert ps.names == ["g", "mean(v)#sum", "mean(v)#cnt"]
        state = partial_aggregate(plan, [whole])
        s = state.column("mean(v)#sum").to_numpy()
        c = state.column("mean(v)#cnt").to_numpy()
        assert c.sum() == 50
        merged = merge_partials(plan, [state])
        np.testing.assert_allclose(
            merged.column("mean(v)").to_numpy(), s / c, rtol=0, atol=0)

    def test_merge_of_partials_matches_oracle_on_pathological_splits(self):
        """Empty batches, empty-after-filter shards, ragged splits: the
        merged (sum, count) state stays within 1e-9 of the one-pass oracle
        (the retired concat-then-average path returned NaN for any shard
        whose filter emptied a group)."""
        whole, _ = build_table("int64", 300, 7, False, seed=11)
        plan = QueryPlan("t", aggregations=[("mean", "v"), ("sum", "v"),
                                            ("count", "v")],
                         group_by=["g"], predicate=col("v") > 25.0)
        # pathological split: leading/trailing empties, a 1-row sliver, rest
        splits = [whole.slice(0, 0), whole.slice(0, 1), whole.slice(1, 149),
                  whole.slice(150, 0), whole.slice(150, 150)]
        partials = [partial_aggregate(plan, [s], whole.schema) for s in splits]
        merged = merge_partials(plan, partials)
        assert_grouped_equal(aggregate(plan, [whole]), merged)

    def test_empty_after_filter_scalar_mean_is_nan_count_zero(self):
        whole, _ = build_table("int64", 40, 4, False, seed=5)
        plan = QueryPlan("t", aggregations=[("mean", "v"), ("count", "v")],
                         predicate=col("v") > 1e9)
        out = aggregate(plan, [whole])
        assert out["count(v)"] == 0.0
        assert out["mean(v)"] != out["mean(v)"]  # NaN, not a crash or 0

    def test_partial_of_empty_shard_merges_cleanly(self):
        whole, _ = build_table("int64", 60, 6, False, seed=9)
        plan = QueryPlan("t", aggregations=AGGS, group_by=["g"])
        full = partial_aggregate(plan, [whole])
        empty = partial_aggregate(plan, [], schema=whole.schema)
        assert empty.num_rows == 0
        merged = merge_partials(plan, [empty, full, empty])
        assert_grouped_equal(aggregate(plan, [whole]), merged)


# --------------------------------------------------------------------------
# fault-interleaved: replica death mid-grouped-query
# --------------------------------------------------------------------------


class TestFaultInterleavedQuery:
    def test_kill_replica_mid_grouped_query_is_oracle_equal(self):
        """R=2 over TCP: kill one replica after the query is planned but
        before its partial streams drain.  The scheduler fails the dead
        primary's endpoints over to the surviving holders — the merged
        result equals the oracle with zero client-visible errors."""
        whole, batches = build_table("int64", 3000, 17, False, seed=21)
        cl = FlightClusterServer(num_shards=3, replicas=2).serve_tcp()
        try:
            cl.add_dataset("big", batches)
            cc = FlightClusterClient(
                f"tcp://127.0.0.1:{cl.port}", max_streams=3, window=2)
            plan = QueryPlan("big", aggregations=AGGS, group_by=["g"])
            info = cc.query_info(plan)
            FaultInjector(cl).kill(0)  # verbs fail + connections sever
            table, _ = cc.scheduler().fetch(info)
            assert table.batches, "no partial states drained"
            got = merge_partials(plan, list(table.batches))
            assert_grouped_equal(aggregate(plan, [whole]), got)
        finally:
            cl.shutdown()

    @pytest.mark.slow
    def test_grouped_queries_survive_replica_churn(self):
        """Churn variant: repeated grouped queries while replicas die and
        revive between (and across) rounds — every merged result stays
        oracle-equal and no round surfaces an error."""
        whole, batches = build_table("int64", 2000, 11, False, seed=33)
        cl = FlightClusterServer(num_shards=4, replicas=2).serve_tcp()
        try:
            cl.add_dataset("big", batches)
            cc = FlightClusterClient(
                f"tcp://127.0.0.1:{cl.port}", max_streams=4, window=2)
            plan = QueryPlan("big", aggregations=AGGS, group_by=["g"])
            oracle = aggregate(plan, [whole])
            inj = FaultInjector(cl)
            for round_ in range(6):
                victim = round_ % 4
                inj.kill(victim)
                # fresh scheduler per round: connections severed by the
                # kill must not be replayed from the client cache
                got, _ = cc.aggregate(plan, max_streams=4)
                assert_grouped_equal(oracle, got)
                inj.revive(victim)
        finally:
            cl.shutdown()

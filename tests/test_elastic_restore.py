"""Elastic scaling end-to-end: checkpoint on one mesh, restore on another.

Runs in a subprocess with 8 forced host devices (device count locks at jax
init).  Saves params sharded on a (4,2) mesh, restores them onto (2,2) and
(8,1) meshes via the resharding restore path, and verifies values — the
mechanism behind TrainSupervisor + plan_reshape recovery.
"""
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parents[1]


@pytest.mark.slow
def test_checkpoint_reshards_across_meshes(tmp_path):
    code = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
        from repro.distributed.checkpoint import CheckpointManager

        devs = np.array(jax.devices())
        mesh_a = Mesh(devs.reshape(4, 2), ("data", "model"))
        w = jnp.arange(64 * 32, dtype=jnp.float32).reshape(64, 32)
        b = jnp.arange(32, dtype=jnp.bfloat16)
        sh_a = {{"w": NamedSharding(mesh_a, P("data", "model")),
                "b": NamedSharding(mesh_a, P("model"))}}
        state = {{"w": jax.device_put(w, sh_a["w"]), "b": jax.device_put(b, sh_a["b"])}}
        mgr = CheckpointManager(r"{tmp_path}")
        mgr.save(1, state)

        # restore onto a *different* mesh shape (elastic shrink) and layout
        mesh_b = Mesh(devs[:4].reshape(2, 2), ("data", "model"))
        sh_b = {{"w": NamedSharding(mesh_b, P("model", "data")),
                "b": NamedSharding(mesh_b, P(None))}}
        out = mgr.restore(1, state, shardings=sh_b)
        assert out["w"].sharding.mesh.shape == {{"data": 2, "model": 2}}
        np.testing.assert_array_equal(np.asarray(out["w"]), np.asarray(w))
        np.testing.assert_array_equal(np.asarray(out["b"], np.float32),
                                      np.asarray(b, np.float32))

        # and onto a bigger DP-only mesh (elastic grow)
        mesh_c = Mesh(devs.reshape(8), ("data",))
        sh_c = {{"w": NamedSharding(mesh_c, P("data")), "b": NamedSharding(mesh_c, P())}}
        out2 = mgr.restore(1, state, shardings=sh_c)
        np.testing.assert_array_equal(np.asarray(out2["w"]), np.asarray(w))
        print("ELASTIC_OK")
    """)
    proc = subprocess.run([sys.executable, "-c", code], capture_output=True, text=True,
                          env={"PYTHONPATH": str(REPO / "src"), "PATH": "/usr/bin:/bin"},
                          timeout=600)
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "ELASTIC_OK" in proc.stdout

"""Cluster layer: shard placement, parallel DoGet/DoPut, failover, hedging."""
import json
import time

import numpy as np
import pytest

from repro.core import RecordBatch
from repro.core.flight import (
    Action,
    FlightClient,
    FlightClusterClient,
    FlightClusterServer,
    FlightDescriptor,
    FlightEndpoint,
    FlightInfo,
    HashPlacement,
    Location,
    ParallelStreamScheduler,
    RoundRobinPlacement,
    Ticket,
)


def make_batches(n=8, rows=500, seed=0):
    rng = np.random.default_rng(seed)
    return [RecordBatch.from_numpy({
        "k": rng.integers(0, 40, rows).astype(np.int64),
        "v": rng.standard_normal(rows),
    }) for _ in range(n)]


def sorted_rows(table_or_batches):
    batches = getattr(table_or_batches, "batches", table_or_batches)
    rows = [r for b in batches for r in b.to_rows()]
    return sorted(rows)


# --------------------------------------------------------------------------
# placement
# --------------------------------------------------------------------------


class TestPlacement:
    def test_round_robin_is_deterministic_and_balanced(self):
        batches = make_batches(8)
        a = RoundRobinPlacement().assign(batches, 4)
        b = RoundRobinPlacement().assign(batches, 4)
        assert [len(s) for s in a] == [2, 2, 2, 2]
        for sa, sb in zip(a, b):
            assert all(x == y for x, y in zip(sa, sb))

    def test_hash_placement_deterministic_across_instances(self):
        batches = make_batches(4)
        p1, p2 = HashPlacement("k"), HashPlacement("k")
        a = p1.assign(batches, 4)
        b = p2.assign(batches, 4)
        for sa, sb in zip(a, b):
            assert sorted_rows(sa) == sorted_rows(sb)

    def test_hash_placement_colocates_keys(self):
        batches = make_batches(4)
        shards = HashPlacement("k").assign(batches, 4)
        seen = {}
        for sid, part in enumerate(shards):
            for b in part:
                for k in b.column("k").to_pylist():
                    assert seen.setdefault(k, sid) == sid, f"key {k} split across shards"
        assert sum(b.num_rows for part in shards for b in part) == 2000

    def test_cluster_add_dataset_matches_freestanding_placement(self):
        batches = make_batches(6)
        cl1 = FlightClusterServer(num_shards=3, placement="hash", hash_key="k")
        cl2 = FlightClusterServer(num_shards=3, placement="hash", hash_key="k")
        cl1.add_dataset("ds", batches)
        cl2.add_dataset("ds", batches)
        for s1, s2 in zip(cl1.shards, cl2.shards):
            assert sorted_rows(s1.dataset("ds")) == sorted_rows(s2.dataset("ds"))


# --------------------------------------------------------------------------
# parallel DoGet
# --------------------------------------------------------------------------


class TestParallelDoGet:
    @pytest.fixture(params=["inproc", "tcp"])
    def cluster(self, request):
        cl = FlightClusterServer(num_shards=4, batches_per_endpoint=1)
        cl.add_dataset("ds", make_batches())
        if request.param == "tcp":
            cl.serve_tcp()
            yield cl, FlightClusterClient(f"tcp://127.0.0.1:{cl.port}", max_streams=4)
            cl.shutdown()
        else:
            yield cl, FlightClusterClient(cl, max_streams=4)

    def test_parallel_equals_serial_bytes_and_rows(self, cluster):
        cl, cc = cluster
        table, stats = cc.read("ds")
        serial = cl.dataset("ds")  # shard-ordered gather
        assert table.num_rows == sum(b.num_rows for b in serial) == 4000
        assert table.nbytes() == sum(b.nbytes() for b in serial)
        # ordered mode reproduces the exact shard-ordered stream
        assert all(a == b for a, b in zip(table.batches, serial))
        assert stats.streams == 4

    def test_unordered_mode_same_multiset(self, cluster):
        cl, cc = cluster
        table, _ = cc.read("ds", ordered=False)
        assert sorted_rows(table) == sorted_rows(cl.dataset("ds"))

    def test_info_carries_shard_metadata(self, cluster):
        _, cc = cluster
        info = cc.info("ds")
        assert info.shard_spec is not None
        assert info.shard_spec.scheme == "round_robin"
        assert info.shard_spec.num_shards == 4
        shards = {ep.shard for ep in info.endpoints}
        assert shards == {0, 1, 2, 3}

    def test_head_gather_doget_serves_whole_dataset(self, cluster):
        cl, _ = cluster
        head = FlightClient(cl)
        got = list(head.do_get(Ticket.for_range("ds", 0, 10**9)))
        assert sum(b.num_rows for b in got) == 4000


# --------------------------------------------------------------------------
# parallel DoPut
# --------------------------------------------------------------------------


class TestParallelDoPut:
    @pytest.mark.parametrize("transport", ["inproc", "tcp"])
    def test_sharded_write_roundtrip(self, transport):
        cl = FlightClusterServer(num_shards=3)
        batches = make_batches(6, rows=200, seed=7)
        try:
            if transport == "tcp":
                cl.serve_tcp()
                cc = FlightClusterClient(f"tcp://127.0.0.1:{cl.port}")
            else:
                cc = FlightClusterClient(cl)
            stats = cc.write("up", batches)
            assert stats.rows == 1200
            assert stats.streams == 3  # one DoPut stream per shard
            table, _ = cc.read("up")
            assert sorted_rows(table) == sorted_rows(batches)
        finally:
            cl.shutdown()

    def test_hash_write_respects_placement(self):
        cl = FlightClusterServer(num_shards=4, placement="hash", hash_key="k")
        cc = FlightClusterClient(cl)
        cc.write("up", make_batches(4, rows=300, seed=3))
        seen = {}
        for sid, shard in enumerate(cl.shards):
            for b in shard.dataset("up"):
                for k in b.column("k").to_pylist():
                    assert seen.setdefault(k, sid) == sid
        st = json.loads(cc.head.do_action(Action("stats"))[0].body)
        assert sum(s["up"]["rows"] for s in st["shards"] if "up" in s) == 1200

    def test_head_doput_repartitions(self):
        cl = FlightClusterServer(num_shards=2)
        head = FlightClient(cl)
        batches = make_batches(4, rows=100)
        w = head.do_put(FlightDescriptor.for_path("h"), batches[0].schema)
        for b in batches:
            w.write_batch(b)
        stats = w.close()
        assert stats["rows"] == 400
        assert [len(s.dataset("h")) for s in cl.shards] == [2, 2]


# --------------------------------------------------------------------------
# failure handling
# --------------------------------------------------------------------------


class TestFailover:
    def test_dead_location_fails_over_to_replica(self):
        """First location refuses connections; the scheduler resumes the
        idempotent range ticket on the live replica."""
        cl = FlightClusterServer(num_shards=2, batches_per_endpoint=1).serve_tcp()
        cl.add_dataset("ds", make_batches(4))
        try:
            info = FlightClient(f"tcp://127.0.0.1:{cl.port}").get_flight_info(
                FlightDescriptor.for_path("ds"))
            dead = Location.for_tcp("127.0.0.1", 1)  # nothing listens here
            wounded = FlightInfo(
                info.schema, info.descriptor,
                [FlightEndpoint(ep.ticket, (dead, *ep.locations), ep.app_metadata)
                 for ep in info.endpoints],
                info.total_records, info.total_bytes, info.shard_spec)
            sched = ParallelStreamScheduler(
                lambda loc: FlightClient(loc), max_streams=4)
            table, stats = sched.fetch(wounded)
            assert table.num_rows == 2000
            assert stats.retries >= len(wounded.endpoints)
        finally:
            cl.shutdown()

    def test_hedged_read_beats_slow_shard(self):
        """A straggling shard's ticket is re-issued after hedge_after and the
        replica's answer wins."""
        cl = FlightClusterServer(num_shards=2, batches_per_endpoint=1).serve_tcp()
        cl.add_dataset("ds", make_batches(4))
        slow = {"n": 0}
        shard0 = cl.shards[0]
        orig = shard0.do_get_impl

        def sometimes_slow(ticket):
            if slow["n"] == 0:
                slow["n"] += 1
                time.sleep(1.5)
            return orig(ticket)

        shard0.do_get_impl = sometimes_slow
        try:
            cc = FlightClusterClient(
                f"tcp://127.0.0.1:{cl.port}", max_streams=4, hedge_after=0.15)
            t0 = time.perf_counter()
            table, stats = cc.read("ds")
            dt = time.perf_counter() - t0
            assert table.num_rows == 2000
            assert stats.hedges >= 1
            assert dt < 1.4  # did not wait out the straggler
        finally:
            cl.shutdown()

    def test_hedged_read_with_all_replicas_dead_raises(self):
        """All attempts failing must raise, not hang the fetch forever."""
        cl = FlightClusterServer(num_shards=1)
        cl.add_dataset("ds", make_batches(1))
        info = FlightClusterClient(cl).info("ds")
        dead = Location.for_tcp("127.0.0.1", 1)
        doomed = FlightInfo(
            info.schema, info.descriptor,
            [FlightEndpoint(ep.ticket, (dead,), ep.app_metadata)
             for ep in info.endpoints],
            info.total_records, info.total_bytes, info.shard_spec)
        sched = ParallelStreamScheduler(
            lambda loc: FlightClient(loc or dead), hedge_after=0.05)
        from repro.core.flight import FlightUnavailableError
        t0 = time.perf_counter()
        with pytest.raises(FlightUnavailableError):
            sched.fetch(doomed)
        assert time.perf_counter() - t0 < 10

    def test_non_hedged_failover_crosses_hosts_via_client_factory(self):
        """read_all_parallel with a dead primary client reaches the replica
        through client_factory even without a hedge timer."""
        from repro.core.flight import InMemoryFlightServer
        srv = InMemoryFlightServer(batches_per_endpoint=1).serve_tcp()
        srv.add_dataset("ds", make_batches(2))
        try:
            live = FlightClient(f"tcp://127.0.0.1:{srv.port}")
            info = live.get_flight_info(FlightDescriptor.for_path("ds"))
            dead_primary = FlightClient("tcp://127.0.0.1:1")
            tcp_only = FlightInfo(
                info.schema, info.descriptor,
                [FlightEndpoint(
                    ep.ticket,
                    tuple(l for l in ep.locations if l.uri.startswith("tcp://")),
                    ep.app_metadata) for ep in info.endpoints],
                info.total_records, info.total_bytes)
            table, stats = dead_primary.read_all_parallel(
                tcp_only, client_factory=lambda loc: FlightClient(loc))
            assert table.num_rows == 1000
            assert stats.retries >= 1
        finally:
            srv.shutdown()

    def test_empty_dataset_reads_as_zero_rows(self):
        """Hash-writing only zero-row batches yields a readable empty table."""
        cl = FlightClusterServer(num_shards=2, placement="hash", hash_key="k")
        cc = FlightClusterClient(cl)
        empty = RecordBatch.from_numpy({
            "k": np.array([], dtype=np.int64), "v": np.array([], dtype=np.float64)})
        stats = cc.write("void", [empty])
        assert stats.rows == 0
        table, rstats = cc.read("void")
        assert table.num_rows == 0
        assert table.schema == empty.schema

    def test_failed_shard_ticket_is_idempotent(self):
        """Re-reading the same shard ticket after a failure returns identical
        batches (the property hedged reads rely on)."""
        cl = FlightClusterServer(num_shards=2, batches_per_endpoint=1)
        cl.add_dataset("ds", make_batches(4))
        cc = FlightClusterClient(cl)
        info = cc.info("ds")
        ep = info.endpoints[0]
        client = cl.client_factory()(ep.locations[0])
        a = list(client.do_get(ep.ticket))
        b = list(client.do_get(ep.ticket))
        assert all(x == y for x, y in zip(a, b)) and len(a) == len(b)


class TestConnectionHygiene:
    def test_server_error_returns_connection_to_pool(self):
        """A Flight-level refusal leaves the channel clean and pooled —
        scheduler failover loops must not leak a socket per attempt."""
        from repro.core.flight import InMemoryFlightServer
        srv = InMemoryFlightServer().serve_tcp()
        srv.add_dataset("ds", make_batches(1))
        try:
            c = FlightClient(f"tcp://127.0.0.1:{srv.port}")
            from repro.core.flight import FlightError
            for _ in range(3):
                with pytest.raises(FlightError):
                    list(c.do_get(Ticket.for_range("nope", 0, 1)))
            assert c._conn_pool.qsize() == 1  # same conn reused, none leaked
            assert len(c.list_flights()) == 1  # channel still healthy
        finally:
            srv.shutdown()


class TestClusterActions:
    def test_shard_locations_action_over_tcp(self):
        cl = FlightClusterServer(num_shards=3, placement="hash", hash_key="k").serve_tcp()
        try:
            c = FlightClient(f"tcp://127.0.0.1:{cl.port}")
            layout = json.loads(c.do_action(Action("shard-locations"))[0].body)
            assert layout["scheme"] == "hash" and layout["key"] == "k"
            assert len(layout["shards"]) == 3
            for entry in layout["shards"]:
                assert any(u.startswith("tcp://") for u in entry["locations"])
        finally:
            cl.shutdown()

    def test_drop_removes_from_all_shards(self):
        cl = FlightClusterServer(num_shards=2)
        cl.add_dataset("ds", make_batches(2))
        FlightClient(cl).do_action(Action("drop", b"ds"))
        assert all("ds" not in s._store for s in cl.shards)
        names = FlightClient(cl).do_action(Action("list-names"))[0].body.decode()
        assert "ds" not in names

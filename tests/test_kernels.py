"""Pallas kernels vs ref.py oracles: shape/dtype sweeps + property tests.

All kernels run in interpret mode on CPU (the TPU lowering path is the same
kernel body; interpret executes it in Python per the assignment).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref


# ---------------------------------------------------------------- varlen --
@pytest.mark.parametrize("dtype", [np.int32, np.float32])
@pytest.mark.parametrize("n,max_len", [(8, 8), (32, 16), (64, 33), (16, 128)])
def test_varlen_unpack_sweep(n, max_len, dtype):
    rng = np.random.default_rng(n * max_len)
    lens = rng.integers(0, 2 * max_len, n)
    offs = np.zeros(n + 1, np.int32)
    np.cumsum(lens, out=offs[1:])
    vals = (rng.standard_normal(max(offs[-1], 1)) * 100).astype(dtype)
    got, glens = ops.varlen_unpack(jnp.asarray(offs), jnp.asarray(vals), max_len,
                                   use_pallas=True)
    want, wlens = ref.varlen_unpack_ref(jnp.asarray(offs), jnp.asarray(vals), max_len)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    np.testing.assert_array_equal(np.asarray(glens), np.asarray(wlens))


@settings(max_examples=20, deadline=None)
@given(st.lists(st.integers(0, 40), min_size=8, max_size=8),
       st.integers(1, 24))
def test_prop_varlen_unpack(lens, max_len):
    offs = np.zeros(9, np.int32)
    np.cumsum(lens, out=offs[1:])
    vals = np.arange(max(offs[-1], 1), dtype=np.int32)
    got, gl = ops.varlen_unpack(jnp.asarray(offs), jnp.asarray(vals), max_len,
                                use_pallas=True)
    want, wl = ref.varlen_unpack_ref(jnp.asarray(offs), jnp.asarray(vals), max_len)
    assert np.array_equal(np.asarray(got), np.asarray(want))
    # invariant: row i reproduces values[offs[i]:offs[i]+len]
    for i in range(8):
        L = min(lens[i], max_len)
        assert np.array_equal(np.asarray(got)[i, :L], vals[offs[i]:offs[i] + L])


# -------------------------------------------------------------- quantize --
@pytest.mark.parametrize("m,k", [(8, 128), (64, 256), (256, 384), (16, 1024)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_quantize_sweep(m, k, dtype):
    rng = np.random.default_rng(m + k)
    x = jnp.asarray(rng.standard_normal((m, k)) * 10, dtype)
    q1, s1 = ops.quantize(x, use_pallas=True)
    q2, s2 = ref.quantize_ref(x)
    # bf16 inputs can land exactly on .5 ties; kernel/ref may round either way
    dq = np.abs(np.asarray(q1, np.int32) - np.asarray(q2, np.int32))
    assert dq.max() <= 1 and (dq > 0).mean() < 1e-3, (dq.max(), (dq > 0).mean())
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=1e-6)
    # round-trip error bounded by scale/2 per element
    back = ops.dequantize(q1, s1, use_pallas=True)
    err = np.abs(np.asarray(back, np.float32) - np.asarray(x, np.float32))
    # scale/2 + ulp slack: bf16 inputs can tie exactly at the rounding boundary
    bound = np.repeat(np.asarray(s1), 128, axis=-1) * 0.505 + 1e-5
    assert (err <= bound).all()


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2**32 - 1))
def test_prop_quantize_roundtrip_bound(seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((8, 128)) * rng.uniform(0.01, 100))
    q, s = ops.quantize(x, use_pallas=True)
    back = np.asarray(ops.dequantize(q, s, use_pallas=True))
    amax = np.abs(np.asarray(x)).max(axis=-1, keepdims=True)
    assert (np.abs(back - np.asarray(x)) <= amax / 127.0 * 0.5 + 1e-7).all()


# ------------------------------------------------------ selection gather --
@pytest.mark.parametrize("n,d,m", [(64, 32, 16), (128, 128, 64), (100, 7, 8)])
def test_selection_gather_sweep(n, d, m):
    rng = np.random.default_rng(n * d)
    vals = jnp.asarray(rng.standard_normal((n, d)), jnp.float32)
    idx = jnp.asarray(rng.integers(-1, n, m), jnp.int32)
    got = ops.selection_gather(vals, idx, use_pallas=True)
    want = ref.selection_gather_ref(vals, idx)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want))


# ----------------------------------------------------------- flash decode --
@pytest.mark.parametrize("b,h,s,d", [(1, 2, 128, 32), (2, 4, 512, 64), (4, 1, 1024, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_decode_sweep(b, h, s, d, dtype):
    rng = np.random.default_rng(b * s)
    q = jnp.asarray(rng.standard_normal((b, h, d)), dtype)
    k = jnp.asarray(rng.standard_normal((b, s, h, d)), dtype)
    v = jnp.asarray(rng.standard_normal((b, s, h, d)), dtype)
    length = jnp.asarray(rng.integers(1, s + 1, b), jnp.int32)
    got = ops.flash_decode(q, k, v, length, use_pallas=True)
    want = ref.flash_decode_ref(q, k, v, length)
    atol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=atol)


def test_flash_decode_masks_beyond_length():
    """Values past `length` must not affect the output."""
    b, h, s, d = 1, 1, 256, 32
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.standard_normal((b, h, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, s, h, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, s, h, d)), jnp.float32)
    out1 = ops.flash_decode(q, k, v, jnp.asarray([100]), use_pallas=True)
    k2 = k.at[:, 100:].set(1e6)
    v2 = v.at[:, 100:].set(-1e6)
    out2 = ops.flash_decode(q, k2, v2, jnp.asarray([100]), use_pallas=True)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2), atol=1e-6)

"""Unit tests for the trip-count-aware HLO analyzer (the §Roofline engine)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.hloanalysis import analyze_module


def _compile(fn, *specs):
    return jax.jit(fn).lower(*specs).compile().as_text()


def test_matmul_flops_exact():
    M, N, K = 128, 256, 512
    t = _compile(lambda a, b: a @ b,
                 jax.ShapeDtypeStruct((M, K), jnp.float32),
                 jax.ShapeDtypeStruct((K, N), jnp.float32))
    c = analyze_module(t)
    assert c.flops == 2 * M * N * K


def test_scan_multiplies_by_trip_count():
    n_iters, d = 12, 64

    def f(x, ws):
        return jax.lax.scan(lambda c, w: (c @ w, None), x, ws)[0]

    t = _compile(f, jax.ShapeDtypeStruct((d, d), jnp.float32),
                 jax.ShapeDtypeStruct((n_iters, d, d), jnp.float32))
    c = analyze_module(t)
    assert c.flops == n_iters * 2 * d ** 3


def test_nested_scan_trips_compose():
    d = 32

    def inner(x, ws):
        return jax.lax.scan(lambda c, w: (c @ w, None), x, ws)[0]

    def outer(x, ws):
        return jax.lax.scan(lambda c, w: (inner(c, w), None), x, ws)[0]

    t = _compile(outer, jax.ShapeDtypeStruct((d, d), jnp.float32),
                 jax.ShapeDtypeStruct((3, 4, d, d), jnp.float32))
    c = analyze_module(t)
    assert c.flops == 3 * 4 * 2 * d ** 3


def test_bytes_scale_with_shapes():
    big = _compile(lambda a, b: a + b,
                   jax.ShapeDtypeStruct((1024, 1024), jnp.float32),
                   jax.ShapeDtypeStruct((1024, 1024), jnp.float32))
    small = _compile(lambda a, b: a + b,
                     jax.ShapeDtypeStruct((64, 64), jnp.float32),
                     jax.ShapeDtypeStruct((64, 64), jnp.float32))
    cb, cs = analyze_module(big), analyze_module(small)
    assert cb.bytes / cs.bytes == pytest.approx((1024 / 64) ** 2, rel=0.3)


def test_scan_stacked_weights_not_charged_per_iteration():
    """The fusion slice-charging rule: per-iteration bytes see one layer's
    weights, not the whole stack."""
    L, d = 16, 256

    def f(x, ws):
        return jax.lax.scan(lambda c, w: (jnp.tanh(c @ w), None), x, ws)[0]

    t = _compile(f, jax.ShapeDtypeStruct((d, d), jnp.float32),
                 jax.ShapeDtypeStruct((L, d, d), jnp.float32))
    c = analyze_module(t)
    stack_bytes = L * d * d * 4
    # if the full stack were charged per iteration we'd see ~= L * stack;
    # slice-charging keeps it near one stack pass + activation traffic
    assert c.bytes < 0.8 * L * stack_bytes, c.bytes

"""XGBatch-analogue (paper Fig 11): a batch-scoring microservice over Flight.

Clients stream RecordBatches of token lists through DoExchange; the service
scores them with an LM and streams results back — zero (de)serialization at
both boundaries.

  PYTHONPATH=src python examples/scoring_service.py
"""
import time

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.core import RecordBatch
from repro.core.flight import FlightClient, FlightDescriptor
from repro.distributed.sharding import single_device_ctx
from repro.models.lm import LM
from repro.serving import LMScoringService

cfg = get_smoke_config("internlm2_1_8b")
model = LM(cfg, single_device_ctx())
params, _ = model.init(jax.random.key(0))
svc = LMScoringService(model, params, max_seq=64).serve_tcp()
print(f"scoring service up on tcp://127.0.0.1:{svc.port}")

rng = np.random.default_rng(1)
client = FlightClient(f"tcp://127.0.0.1:{svc.port}")
reqs = [[int(t) for t in rng.integers(1, cfg.vocab, rng.integers(4, 60))]
        for _ in range(64)]
schema = RecordBatch.from_pydict({"tokens": [reqs[0]]}).schema

# pipelined streaming exchange: the feeder thread pushes request batches
# while this thread drains scored results (no per-batch round trips)
ex = client.do_exchange_stream(FlightDescriptor.for_path("score"), schema)
t0 = time.perf_counter()
ex.feed([RecordBatch.from_pydict({"tokens": reqs[s:s + 16]}, schema)
         for s in range(0, len(reqs), 16)])
n = 0
for out in ex:
    n += out.num_rows
ex.close()
dt = time.perf_counter() - t0
print(f"scored {n} requests in {dt:.2f}s ({n/dt:.0f} req/s); "
      f"sample: {out.slice(0, 3).to_pydict()}")
svc.shutdown()

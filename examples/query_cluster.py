"""Sharded query pushdown: a predicated, projected read fanned across
4 shards through the cluster head node.

The head plans ``GetFlightInfo(QueryCommand)`` into one *query endpoint per
shard*; the parallel stream scheduler pulls all four filtered/projected
streams concurrently, and each shard's ``server-stats`` counters show the
predicate ran where the data lives — only surviving rows crossed the wire.

  PYTHONPATH=src python examples/query_cluster.py
"""
import json

import numpy as np

from repro.core import RecordBatch
from repro.core.flight import (
    Action,
    CallOptions,
    FlightClusterClient,
    FlightClusterServer,
)
from repro.query import QueryPlan, col

rng = np.random.default_rng(0)
n, n_batches = 200_000, 8
batches = [RecordBatch.from_numpy({
    "passenger_count": rng.integers(1, 7, n // n_batches).astype(np.int32),
    "trip_distance": rng.gamma(2.0, 1.5, n // n_batches).astype(np.float32),
    "fare_amount": rng.gamma(3.0, 5.0, n // n_batches).astype(np.float64),
    "tip_amount": rng.gamma(1.0, 2.0, n // n_batches).astype(np.float64),
}) for _ in range(n_batches)]

cluster = FlightClusterServer(num_shards=4).serve_tcp()
cluster.add_dataset("taxi", batches)
client = FlightClusterClient(f"tcp://127.0.0.1:{cluster.port}", max_streams=4,
                             call_options=CallOptions(timeout=30.0))

plan = QueryPlan("taxi",
                 projection=["fare_amount", "trip_distance"],
                 predicate=(col("trip_distance") > 3.0) & (col("passenger_count") >= 2))

info = client.query_info(plan)
print(f"head planned {len(info.endpoints)} per-shard query endpoints: "
      f"shards {sorted(ep.shard for ep in info.endpoints)}")

table, stats = client.query(plan)
print(f"pushdown: {table.num_rows} of {n} rows survived, "
      f"columns {table.schema.names}, {stats.bytes / 1e6:.2f} MB over "
      f"{stats.streams} parallel streams in {stats.seconds * 1e3:.1f} ms")

full, fstats = client.read("taxi")
print(f"full scan for comparison: {fstats.bytes / 1e6:.2f} MB "
      f"({fstats.bytes / max(stats.bytes, 1):.1f}x the wire bytes)")

print("\nper-shard server-stats (the predicate ran shard-side):")
for i, shard in enumerate(cluster.shards):
    st = json.loads(shard.do_action_impl(Action("server-stats"))[0].body)
    print(f"  shard {i}: queries={st['queries_executed']} "
          f"rows_in={st['query_rows_in']} rows_out={st['query_rows_out']} "
          f"({100 * st['query_rows_out'] / max(st['query_rows_in'], 1):.1f}% survived)")

cluster.shutdown()

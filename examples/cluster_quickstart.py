"""Cluster quickstart: shard a dataset over N Flight endpoints, read it back
with parallel streams — the paper's GetFlightInfo → parallel DoGet topology.

  PYTHONPATH=src python examples/cluster_quickstart.py
"""
import numpy as np

from repro.core import RecordBatch
from repro.core.flight import FlightClusterClient, FlightClusterServer

rng = np.random.default_rng(0)
batches = [RecordBatch.from_numpy({
    "user_id": rng.integers(0, 10_000, 250_000).astype(np.int64),
    "value": rng.standard_normal(250_000),
}) for _ in range(8)]

# 1. A 4-shard cluster; round-robin placement balances batches across shards
cluster = FlightClusterServer(num_shards=4)
cluster.add_dataset("events", batches)

# 2. GetFlightInfo answers with one (Location, Ticket) endpoint per shard
client = FlightClusterClient(cluster, max_streams=4)
info = client.info("events")
print(f"endpoints: {len(info.endpoints)} "
      f"(scheme={info.shard_spec.scheme}, shards={info.shard_spec.num_shards})")

# 3. Parallel DoGet fans in all shard streams (ordered reassembly)
table, stats = client.read("events")
print(f"DoGet x{stats.streams} shards: {table.num_rows} rows "
      f"at {stats.mb_per_s:.0f} MB/s")

# 4. Parallel DoPut: partition client-side, write straight to the shards.
#    Hash placement co-locates equal keys — the layout shard-local
#    aggregations want.
hashed = FlightClusterServer(num_shards=4, placement="hash", hash_key="user_id")
hclient = FlightClusterClient(hashed)
wstats = hclient.write("events", batches)
print(f"DoPut x{wstats.streams} shards: {wstats.rows} rows "
      f"at {wstats.mb_per_s:.0f} MB/s")
per_shard = [sum(b.num_rows for b in s.dataset('events')) for s in hashed.shards]
print(f"hash placement rows per shard: {per_shard}")

# 5. Transactional DoPut: the same parallel shard streams, but staged under
#    one txn id and committed by the head's prepare->commit round — the
#    write lands all-or-none (a failed stage aborts every shard's slice)
before = hclient.read("events")[0].num_rows
wstats = hclient.write("events", batches, transactional=True)
after = hclient.read("events")[0].num_rows
print(f"transactional DoPut x{wstats.streams} shards: "
      f"{after - before} rows committed atomically")

# 6. Same topology over TCP: each shard listens on its own port, and a slow
#    shard can be hedged (re-issue its idempotent range ticket on a replica)
cluster.serve_tcp()
remote = FlightClusterClient(f"tcp://127.0.0.1:{cluster.port}",
                             max_streams=4, hedge_after=1.0)
rtable, rstats = remote.read("events")
print(f"TCP DoGet x{rstats.streams}: {rtable.num_rows} rows "
      f"at {rstats.mb_per_s:.0f} MB/s")
cluster.shutdown()

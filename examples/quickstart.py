"""Quickstart: columnar batches, Flight transfer, query pushdown — 30 seconds.

  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import RecordBatch
from repro.core.flight import FlightClient, FlightDescriptor, InMemoryFlightServer
from repro.query import FlightQueryService, QueryPlan, col

# 1. Columnar data — the paper's Table 1, zero-copy from numpy for bulk
batch = RecordBatch.from_pydict({
    "X": [555, 56565, None],
    "Y": ["Arrow", "Data", "!"],
    "Z": [5.7866, 0.0, 3.14],
})
print("Table 1:", batch.to_pydict())

rng = np.random.default_rng(0)
big = RecordBatch.from_numpy({
    "id": np.arange(1_000_000, dtype=np.int64),
    "value": rng.standard_normal(1_000_000),
})

# 2. Flight: serve it, fetch it with parallel streams
server = InMemoryFlightServer(batches_per_endpoint=1).serve_tcp()
server.add_dataset("big", [big.slice(i * 250_000, 250_000) for i in range(4)])
client = FlightClient(f"tcp://127.0.0.1:{server.port}")
info = client.get_flight_info(FlightDescriptor.for_path("big"))
table, stats = client.read_all_parallel(info, max_streams=4)
print(f"DoGet x4 streams: {table.num_rows} rows at {stats.mb_per_s:.0f} MB/s")
server.shutdown()

# 3. Query pushdown: only matching rows/columns cross the wire
svc = FlightQueryService().serve_tcp()
svc.add_dataset("big", [big])
qclient = FlightClient(f"tcp://127.0.0.1:{svc.port}")
plan = QueryPlan("big", projection=["value"], predicate=col("value") > 2.0)
qinfo = qclient.get_flight_info(FlightDescriptor.for_command(plan.serialize()))
qtable, qstats = qclient.read_all_parallel(qinfo, max_streams=4)
print(f"pushdown query: {qtable.num_rows} of {big.num_rows} rows shipped "
      f"({qtable.nbytes() / big.nbytes():.1%} of the bytes)")
svc.shutdown()

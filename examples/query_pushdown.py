"""Dremio-analogue (paper Fig 8): the same query through three protocols.

  PYTHONPATH=src python examples/query_pushdown.py
"""
import numpy as np

from repro.core import RecordBatch
from repro.query import QueryPlan, col
from repro.query.odbc_sim import FlightColumnarProtocol, OdbcProtocol, TurbodbcProtocol

rng = np.random.default_rng(0)
n = 120_000
batches = [RecordBatch.from_pydict({
    "passenger_count": rng.integers(1, 7, n // 4).astype(np.int32),
    "trip_distance": rng.gamma(2.0, 1.5, n // 4).astype(np.float32),
    "fare_amount": rng.gamma(3.0, 5.0, n // 4).astype(np.float64),
    "pickup": [f"2015-01-{d:02d}" for d in rng.integers(1, 29, n // 4)],
}) for _ in range(4)]

plan = QueryPlan("taxi", projection=["fare_amount", "pickup"],
                 predicate=col("trip_distance") > 2.0)

print(f"{'protocol':10s} {'rows':>8s} {'wire MB':>8s} {'total ms':>9s}")
results = {}
for proto in (OdbcProtocol(), TurbodbcProtocol(), FlightColumnarProtocol()):
    _, st = proto.transfer(plan, batches)
    results[proto.name] = st.total_s
    print(f"{proto.name:10s} {st.rows:8d} {st.wire_bytes/1e6:8.2f} "
          f"{st.total_s*1e3:9.1f}")
print(f"\nflight is {results['odbc']/results['flight']:.0f}x faster than odbc, "
      f"{results['turbodbc']/results['flight']:.0f}x faster than turbodbc "
      f"(paper: 30x / 20x)")

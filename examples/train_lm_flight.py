"""End-to-end: train an LM with the Flight data plane (the paper's protocol
feeding the training loop), with checkpoint/restart fault tolerance.

  PYTHONPATH=src python examples/train_lm_flight.py [--steps 150]

This drives the same ``repro.launch.train`` machinery a TPU pod would use,
at a CPU-sized reduced config (a ~100M-class run is the same command with
--d-model 768 --layers 12 on real hardware).
"""
import argparse
import sys

from repro.launch.train import main as train_main

if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=150)
    ap.add_argument("--batch-size", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    args = ap.parse_args()
    sys.argv = [
        "train", "--arch", "internlm2_1_8b", "--smoke",
        "--d-model", "128", "--layers", "4", "--vocab", "2048",
        "--steps", str(args.steps), "--batch-size", str(args.batch_size),
        "--seq-len", str(args.seq_len), "--lr", "1e-3",
        "--ckpt-dir", "/tmp/repro_example_ckpt",
        "--checkpoint-every", str(max(args.steps // 2, 50)),
    ]
    train_main()

"""Chained Flight microservices: filter on server A, score on server B.

The paper's third pillar runs Flight as a *microservice* substrate —
bidirectional DoExchange streams where requests and responses are columnar
batches and both directions stay busy.  This example builds the Mallard-
style topology on the streaming exchange plane (core/flight/exchange.py):

1. server A registers the stock ``filter`` service (a query-engine
   predicate, the same expression tree a QueryCommand pushdown runs);
2. server B registers a custom ``score`` service (a ``MapBatchesService``
   callable living server-side — only its *name* rides the wire);
3. a ``Pipeline`` chains them: rows stream client → A → B → client,
   link by link, each link bounded by its in-flight window — the dataset
   is never materialized client-side.

  PYTHONPATH=src python examples/microservice_pipeline.py
"""
import time

import numpy as np

from repro.core import RecordBatch
from repro.core.flight import (
    ExchangeCommand,
    FlightClient,
    InMemoryFlightServer,
    MapBatchesService,
    Pipeline,
    open_exchange,
)
from repro.query import col

# -- two independent servers, one transform each -------------------------- #
server_a = InMemoryFlightServer("filter-node").serve_tcp()
server_b = InMemoryFlightServer("score-node").serve_tcp()
server_b.services.register(MapBatchesService(
    "score",
    lambda b: RecordBatch.from_numpy({
        "key": b.column("key").to_numpy(),
        "score": np.tanh(b.column("value").to_numpy() / 10.0),
    }),
))
print(f"filter service on tcp://127.0.0.1:{server_a.port}, "
      f"score service on tcp://127.0.0.1:{server_b.port}")

rng = np.random.default_rng(7)
batches = [RecordBatch.from_numpy({
    "key": rng.integers(0, 1 << 16, 2048).astype(np.int64),
    "value": rng.standard_normal(2048) * 10,
}) for _ in range(32)]
schema = batches[0].schema

# -- single-service streaming call (one server) --------------------------- #
stream = open_exchange(
    FlightClient(f"tcp://127.0.0.1:{server_a.port}"),
    ExchangeCommand.for_service("filter", predicate=(col("value") > 0).to_json()),
    schema, batches)
kept = sum(b.num_rows for b in stream)
print(f"filter alone kept {kept}/{32 * 2048} rows "
      f"(server-side stats: {stream.stats})")

# -- the chained pipeline: A filters, B scores ---------------------------- #
pipe = Pipeline([
    (FlightClient(f"tcp://127.0.0.1:{server_a.port}"),
     ExchangeCommand.for_service("filter", predicate=(col("value") > 0).to_json())),
    (FlightClient(f"tcp://127.0.0.1:{server_b.port}"), "score"),
])
t0 = time.perf_counter()
table = pipe.run_all(schema, batches)
dt = time.perf_counter() - t0
assert table.num_rows == kept
assert table.schema.names == ["key", "score"]
print(f"pipeline A→filter→B→score: {table.num_rows} rows in {dt * 1e3:.0f} ms "
      f"({table.nbytes() / dt / 1e6:.0f} MB/s out)")
print(f"per-stage stats: {pipe.stats()}")

server_a.shutdown()
server_b.shutdown()
